//! Fault tolerance on the hybrid torus-of-meshes (ISSUE 3 acceptance):
//! kill (i) one cross-chip SerDes link, (ii) every off-chip link of one
//! gateway tile, (iii) one on-chip mesh link — then drive staggered
//! all-pairs PUT traffic and assert full delivery with intact payloads,
//! zero flits on the dead wires, and no deadlock under the event-driven
//! scheduler. Plus the cross-chip BER + CQ-driven retry loop.
//!
//! The same matrix runs at 4x4x4 chips (ISSUE 6 acceptance) with
//! chip-granular all-pairs traffic: k=4 rings route and recover under
//! the per-channel dateline classes — these scenarios were refused
//! outright (`DatelineHazard`) before the class rework.

use dnp::config::DnpConfig;
use dnp::fault::{self, HierLinkFault};
use dnp::{topology, traffic, Net};

const CHIPS: [u32; 3] = [2, 2, 1];
const TILES: [u32; 2] = [2, 2];
const N: usize = 16;
const LEN: u32 = 8;

const CHIPS4: [u32; 3] = [4, 4, 4];
const NCHIPS4: usize = 64;
const MEM4: usize = 1 << 17; // 64 per-chip RX windows end at 0x14000

/// Inject `faults`, run all-pairs, and assert the acceptance criteria.
fn run_scenario(faults: &[HierLinkFault], label: &str) {
    let cfg = DnpConfig::hybrid();
    let (mut net, wiring) = topology::hybrid_torus_mesh_wired(CHIPS, TILES, &cfg, 1 << 16);
    let slots: Vec<usize> = (0..N).collect();
    traffic::setup_buffers(&mut net, &slots);
    let dead = fault::inject_hybrid(&mut net, &wiring, faults, &cfg)
        .unwrap_or_else(|e| panic!("{label}: fault set must be recoverable: {e}"));
    assert_eq!(dead.len(), faults.len() * 2, "{label}: two wires per fault");

    let plan = traffic::hybrid_all_pairs(CHIPS, TILES, LEN);
    let total = plan.len() as u64;
    let mut feeder = traffic::Feeder::new(plan);
    // `run_plan` is the event-driven scheduler: a missed wake or a routing
    // deadlock shows up as a timeout here.
    traffic::run_plan(&mut net, &mut feeder, 5_000_000)
        .unwrap_or_else(|| panic!("{label}: all-pairs must drain post-fault (deadlock?)"));

    assert_eq!(net.traces.delivered, total, "{label}: every PUT delivered");
    assert_eq!(net.traces.lut_misses, 0, "{label}");
    assert_eq!(net.traces.corrupt_packets, 0, "{label}");

    // Delivery at the right node, for every pair.
    for slot in 0..N {
        for peer in 0..N {
            if peer == slot {
                continue;
            }
            let t = net
                .pkt_of_tag((slot * 100 + peer) as u32)
                .unwrap_or_else(|| panic!("{label}: no trace for {slot} -> {peer}"));
            assert_eq!(t.dst_node, Some(peer), "{label}: {slot} -> {peer} landed elsewhere");
        }
    }

    // Payload integrity: the window node `peer` exposes to source `slot`
    // holds the sender's recognizable pattern (slot << 16 | word index).
    for peer in 0..N {
        for slot in 0..N {
            if peer == slot {
                continue;
            }
            let got = net.dnp(peer).mem.read_slice(traffic::rx_addr(slot), LEN as usize);
            let want: Vec<u32> = (0..LEN).map(|i| (slot as u32) << 16 | i).collect();
            assert_eq!(got, &want[..], "{label}: payload {slot} -> {peer} damaged");
        }
    }

    // The dead wires carried zero flits.
    for ch in dead {
        assert_eq!(
            net.chans.get(ch).words_sent,
            0,
            "{label}: dead channel {ch:?} carried flits"
        );
    }
}

/// Inject `faults` on the 4x4x4 system, run chip-granular all-pairs,
/// and assert the acceptance criteria — the k≥4 twin of `run_scenario`.
fn run_chip_scenario(faults: &[HierLinkFault], label: &str) {
    let cfg = DnpConfig::hybrid();
    let (mut net, wiring) = topology::hybrid_torus_mesh_wired(CHIPS4, TILES, &cfg, MEM4);
    traffic::setup_chip_buffers(&mut net, NCHIPS4);
    let dead = fault::inject_hybrid(&mut net, &wiring, faults, &cfg)
        .unwrap_or_else(|e| panic!("{label}: fault set must be recoverable at k=4: {e}"));
    assert_eq!(dead.len(), faults.len() * 2, "{label}: two wires per fault");

    let plan = traffic::hybrid_chip_all_pairs(CHIPS4, TILES, LEN);
    let total = plan.len() as u64;
    let originals = plan.clone();
    let mut feeder = traffic::Feeder::new(plan);
    traffic::run_plan(&mut net, &mut feeder, 20_000_000)
        .unwrap_or_else(|| panic!("{label}: chip all-pairs must drain post-fault (deadlock?)"));

    assert_eq!(net.traces.delivered, total, "{label}: every PUT delivered");
    assert_eq!(net.traces.lut_misses, 0, "{label}");
    assert_eq!(net.traces.corrupt_packets, 0, "{label}");

    // Delivery at the right node with an intact payload, per chip pair.
    for p in &originals {
        let sc = (p.cmd.tag / NCHIPS4 as u32) as usize;
        let t = net
            .pkt_of_tag(p.cmd.tag)
            .unwrap_or_else(|| panic!("{label}: no trace for tag {}", p.cmd.tag));
        let dst = net.node_of(p.cmd.dst_dnp);
        assert_eq!(t.dst_node, Some(dst), "{label}: tag {} landed elsewhere", p.cmd.tag);
        let got = net.dnp(dst).mem.read_slice(p.cmd.dst_addr, LEN as usize);
        let want: Vec<u32> = (0..LEN).map(|i| (p.node as u32) << 16 | i).collect();
        assert_eq!(got, &want[..], "{label}: payload chip {sc} -> node {dst} damaged");
    }

    // The dead wires carried zero flits.
    for ch in dead {
        assert_eq!(
            net.chans.get(ch).words_sent,
            0,
            "{label}: dead channel {ch:?} carried flits"
        );
    }
}

/// (i) One cross-chip SerDes cable dies: traffic between the two chips
/// detours over the surviving wires.
#[test]
fn dead_serdes_link_all_pairs_recover() {
    run_scenario(
        &[HierLinkFault::Serdes { chip: [0, 0, 0], dim: 0, plus: true }],
        "dead SerDes link",
    );
}

/// (ii) Every off-chip cable of chip (0,0,0)'s dim-0 gateway dies: the
/// dimension's traffic re-homes onto the dim-1 gateway's ring.
#[test]
fn dead_gateway_all_pairs_recover() {
    run_scenario(
        &[
            HierLinkFault::Serdes { chip: [0, 0, 0], dim: 0, plus: true },
            HierLinkFault::Serdes { chip: [0, 0, 0], dim: 0, plus: false },
        ],
        "dead gateway",
    );
}

/// (iii) One on-chip mesh link dies: intra-chip XY detours around it.
#[test]
fn dead_mesh_link_all_pairs_recover() {
    run_scenario(
        &[HierLinkFault::Mesh { chip: [0, 0, 0], tile: [0, 0], dim: 0, plus: true }],
        "dead mesh link",
    );
}

/// Combined hard-fault scenario: a SerDes cable and a mesh link in
/// different chips die at once.
#[test]
fn combined_serdes_and_mesh_faults_recover() {
    run_scenario(
        &[
            HierLinkFault::Serdes { chip: [0, 0, 0], dim: 1, plus: true },
            HierLinkFault::Mesh { chip: [1, 0, 0], tile: [1, 0], dim: 1, plus: true },
        ],
        "combined faults",
    );
}

/// Cross-chip BER soft faults: corrupt payloads are flagged by the
/// destination CQ (`CorruptPayload`) and the traffic-layer retry loop
/// re-issues them until every window holds clean data.
#[test]
fn cross_chip_ber_retry_loop_recovers_payloads() {
    let mut cfg = DnpConfig::hybrid();
    cfg.serdes.ber_per_word = 1e-2; // aggressive: SerDes links only
    let mut net = topology::hybrid_torus_mesh(CHIPS, TILES, &cfg, 1 << 16);
    let slots: Vec<usize> = (0..N).collect();
    traffic::setup_buffers(&mut net, &slots);
    let plan = traffic::hybrid_uniform_random(CHIPS, TILES, 6, 32, 10, 0xFA17_0001);
    let originals = plan.clone();
    let report = traffic::retrying_plan(&mut net, plan, 5_000_000, 40)
        .expect("retry loop must converge");
    // Every corrupt delivery triggered exactly one retry (no LUT misses
    // here), and the loop only returns once a round completes clean.
    assert_eq!(net.traces.lut_misses, 0);
    assert_eq!(report.retries, net.traces.corrupt_packets);
    assert!(
        net.traces.corrupt_packets > 0,
        "BER 1e-2 over {} cross-chip PUTs must corrupt at least one payload",
        originals.len()
    );
    // Final memory state: every targeted window holds the sender's clean
    // pattern (the last write to each window is an uncorrupted delivery).
    for p in &originals {
        let dst = net.node_of(p.cmd.dst_dnp);
        let got = net.dnp(dst).mem.read_slice(p.cmd.dst_addr, p.cmd.len as usize);
        let want: Vec<u32> = (0..p.cmd.len).map(|i| (p.node as u32) << 16 | i).collect();
        assert_eq!(got, &want[..], "window {} -> {dst} left corrupted", p.node);
    }
}

/// 4x4x4 (i): a dead SerDes cable on a k=4 ring — the scenario the
/// pre-class recovery refused outright with `DatelineHazard`.
#[test]
fn dead_serdes_link_4x4x4_recovers() {
    run_chip_scenario(
        &[HierLinkFault::Serdes { chip: [1, 2, 3], dim: 2, plus: true }],
        "4x4x4 dead SerDes link",
    );
}

/// 4x4x4 (ii): every off-chip cable of one chip's dim-0 gateway dies —
/// the dimension's traffic re-homes onto another ring.
#[test]
fn dead_gateway_4x4x4_recovers() {
    run_chip_scenario(
        &[
            HierLinkFault::Serdes { chip: [0, 0, 0], dim: 0, plus: true },
            HierLinkFault::Serdes { chip: [0, 0, 0], dim: 0, plus: false },
        ],
        "4x4x4 dead gateway",
    );
}

/// 4x4x4 (iii): one on-chip mesh link dies — intra-chip XY detours,
/// while every k=4 ring stays on its healthy class routes.
#[test]
fn dead_mesh_link_4x4x4_recovers() {
    run_chip_scenario(
        &[HierLinkFault::Mesh { chip: [2, 1, 0], tile: [0, 0], dim: 0, plus: true }],
        "4x4x4 dead mesh link",
    );
}

/// 4x4x4 combined: a SerDes cable and a mesh link in different chips.
#[test]
fn combined_faults_4x4x4_recover() {
    run_chip_scenario(
        &[
            HierLinkFault::Serdes { chip: [3, 0, 1], dim: 1, plus: true },
            HierLinkFault::Mesh { chip: [1, 3, 2], tile: [1, 0], dim: 1, plus: true },
        ],
        "4x4x4 combined faults",
    );
}

/// 4x4x4 BER + retry: soft faults on the k=4 rings' SerDes links are
/// retried end-to-end until every per-chip window holds clean data.
#[test]
fn cross_chip_ber_retry_4x4x4_recovers_payloads() {
    let mut cfg = DnpConfig::hybrid();
    cfg.serdes.ber_per_word = 1e-3; // SerDes links only
    let mut net = topology::hybrid_torus_mesh(CHIPS4, TILES, &cfg, MEM4);
    traffic::setup_chip_buffers(&mut net, NCHIPS4);
    let plan = traffic::hybrid_chip_all_pairs(CHIPS4, TILES, LEN);
    let originals = plan.clone();
    let report = traffic::retrying_plan(&mut net, plan, 20_000_000, 40)
        .expect("retry loop must converge at 4x4x4");
    assert_eq!(net.traces.lut_misses, 0);
    assert_eq!(report.retries, net.traces.corrupt_packets);
    assert!(
        net.traces.corrupt_packets > 0,
        "BER 1e-3 over {} cross-chip PUTs must corrupt at least one payload",
        originals.len()
    );
    for p in &originals {
        let dst = net.node_of(p.cmd.dst_dnp);
        let got = net.dnp(dst).mem.read_slice(p.cmd.dst_addr, LEN as usize);
        let want: Vec<u32> = (0..LEN).map(|i| (p.node as u32) << 16 | i).collect();
        assert_eq!(got, &want[..], "window of tag {} left corrupted", p.cmd.tag);
    }
}

/// The combination of hard faults and recovered tables still agrees with
/// the paper's reliability contract: no packet is ever dropped, so the
/// per-net packet counters balance exactly.
#[test]
fn recovered_net_conserves_packets() {
    let cfg = DnpConfig::hybrid();
    let (mut net, wiring) = topology::hybrid_torus_mesh_wired(CHIPS, TILES, &cfg, 1 << 16);
    let slots: Vec<usize> = (0..N).collect();
    traffic::setup_buffers(&mut net, &slots);
    fault::inject_hybrid(
        &mut net,
        &wiring,
        &[HierLinkFault::Serdes { chip: [0, 0, 0], dim: 0, plus: true }],
        &cfg,
    )
    .expect("recoverable");
    let plan = traffic::hybrid_halo_exchange(CHIPS, TILES, 32);
    let total = plan.len() as u64;
    let mut feeder = traffic::Feeder::new(plan);
    traffic::run_plan(&mut net, &mut feeder, 5_000_000).expect("halo drains post-fault");
    assert_eq!(net.traces.delivered, total);
    let sent: u64 = sum_dnp(&net, |d| d.pkts_sent);
    let recv: u64 = sum_dnp(&net, |d| d.pkts_recv);
    assert_eq!(sent, recv, "no packet may be dropped (paper Sec. II-C)");
}

fn sum_dnp(net: &Net, f: impl Fn(&dnp::dnp::DnpNode) -> u64) -> u64 {
    net.nodes.iter().filter_map(|n| n.as_dnp().map(&f)).sum()
}
