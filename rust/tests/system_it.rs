//! System-level integration: whole-net scenarios across topologies,
//! runtime reconfiguration, fault injection and error handling.

use dnp::config::{ArbPolicy, DnpConfig, RouteOrder};
use dnp::dnp::regs::{encode_route_order, REG_ROUTE_PRIORITY};
use dnp::fault::{apply_tables, recompute_tables, LinkFault};
use dnp::metrics;
use dnp::packet::{AddrFormat, DnpAddr};
use dnp::rdma::{Command, CqReader, EventKind};
use dnp::topology;
use dnp::traffic;
use dnp::Net;

fn dnp_slots(net: &Net) -> Vec<(usize, DnpAddr)> {
    net.nodes
        .iter()
        .enumerate()
        .filter_map(|(i, n)| n.as_dnp().map(|d| (i, d.addr)))
        .collect()
}

/// Every pair of a 4×3×2 torus can exchange a PUT (wormhole + VC dateline
/// under a dense, staggered load).
#[test]
fn torus_4x3x2_all_pairs() {
    let cfg = DnpConfig::shapes_rdt();
    let dims = [4, 3, 2];
    let mut net = topology::torus3d(dims, &cfg, 1 << 16);
    let nodes = dnp_slots(&net);
    let slots: Vec<usize> = nodes.iter().map(|&(i, _)| i).collect();
    traffic::setup_buffers(&mut net, &slots);
    net.traces.enabled = false;
    let mut plan = Vec::new();
    for (slot, &(node, _)) in nodes.iter().enumerate() {
        for (pslot, &(_, peer)) in nodes.iter().enumerate() {
            if pslot == slot {
                continue;
            }
            plan.push(traffic::Planned {
                node,
                at: (slot as u64) * 7 + (pslot as u64) * 3,
                cmd: Command::put(traffic::TX_BASE, peer, traffic::rx_addr(slot), 8)
                    .with_tag((slot * 100 + pslot) as u32),
            });
        }
    }
    let total = plan.len() as u64;
    let mut feeder = traffic::Feeder::new(plan);
    traffic::run_plan(&mut net, &mut feeder, 5_000_000).expect("all-pairs drains");
    assert_eq!(net.traces.delivered, total);
    assert_eq!(net.traces.lut_misses, 0);
    assert_eq!(net.traces.corrupt_packets, 0);
}

/// MTNoC: all pairs across the Spidergon NoC (DNI + aFirst + dateline).
#[test]
fn spidergon_chip_all_pairs() {
    let cfg = DnpConfig::mtnoc();
    let mut net = topology::spidergon_chip(8, &cfg, 1 << 16);
    let nodes = dnp_slots(&net);
    assert_eq!(nodes.len(), 8);
    let slots: Vec<usize> = nodes.iter().map(|&(i, _)| i).collect();
    traffic::setup_buffers(&mut net, &slots);
    let mut plan = Vec::new();
    for (slot, &(node, _)) in nodes.iter().enumerate() {
        for (pslot, &(_, peer)) in nodes.iter().enumerate() {
            if pslot == slot {
                continue;
            }
            plan.push(traffic::Planned {
                node,
                at: slot as u64 * 5,
                cmd: Command::put(traffic::TX_BASE, peer, traffic::rx_addr(slot), 16)
                    .with_tag((slot * 10 + pslot) as u32),
            });
        }
    }
    let total = plan.len() as u64;
    let mut feeder = traffic::Feeder::new(plan);
    traffic::run_plan(&mut net, &mut feeder, 5_000_000).expect("NoC traffic drains");
    assert_eq!(net.traces.delivered, total);
}

/// MT2D: all pairs across the on-chip 2×4 mesh.
#[test]
fn mesh_chip_all_pairs() {
    let cfg = DnpConfig::mt2d();
    let mut net = topology::mesh2d_chip([4, 2], &cfg, 1 << 16);
    let nodes = dnp_slots(&net);
    let slots: Vec<usize> = nodes.iter().map(|&(i, _)| i).collect();
    traffic::setup_buffers(&mut net, &slots);
    let mut plan = Vec::new();
    for (slot, &(node, _)) in nodes.iter().enumerate() {
        for (pslot, &(_, peer)) in nodes.iter().enumerate() {
            if pslot == slot {
                continue;
            }
            plan.push(traffic::Planned {
                node,
                at: 0,
                cmd: Command::put(traffic::TX_BASE, peer, traffic::rx_addr(slot), 16)
                    .with_tag((slot * 10 + pslot) as u32),
            });
        }
    }
    let total = plan.len() as u64;
    let mut feeder = traffic::Feeder::new(plan);
    traffic::run_plan(&mut net, &mut feeder, 5_000_000).expect("mesh traffic drains");
    assert_eq!(net.traces.delivered, total);
}

/// Run-time route-priority rewrite (Sec. III-A): software writes the
/// priority register; subsequent packets take the other dimension first.
#[test]
fn route_priority_register_changes_paths() {
    let cfg = DnpConfig::shapes_rdt(); // default ZYX
    let dims = [3, 3, 3];
    let fmt = AddrFormat::Torus3D { dims };
    let mut net = topology::torus3d(dims, &cfg, 1 << 16);
    let dst = fmt.encode(&[1, 0, 1]);
    let dst_node = net.node_of(dst);
    net.dnp_mut(dst_node).register_buffer(0x4000, 1024, 0);

    // ZYX: first hop consumes Z → port base + 2*2 = off-chip port 4+n.
    net.issue(0, Command::put(0x40, dst, 0x4000, 1).with_tag(1));
    net.run_until_idle(100_000).unwrap();
    let first_hop_port = |net: &Net, tag: u32| -> usize {
        net.traces
            .pkts
            .values()
            .find(|p| p.tag == tag)
            .and_then(|p| p.tx_hops.iter().find(|(n, _, _)| *n == 0))
            .map(|&(_, p, _)| p)
            .expect("tx hop")
    };
    let zyx_port = first_hop_port(&net, 1);
    assert_eq!(zyx_port, cfg.n_ports + 2 * 2, "Z consumed first under ZYX");

    // Rewrite the priority register to XYZ and send again.
    net.dnp_mut(0)
        .regs
        .write(REG_ROUTE_PRIORITY, encode_route_order(RouteOrder::XYZ));
    net.issue(0, Command::put(0x40, dst, 0x4000, 1).with_tag(2));
    net.run_until_idle(100_000).unwrap();
    let xyz_port = first_hop_port(&net, 2);
    assert_eq!(xyz_port, cfg.n_ports, "X consumed first under XYZ");
}

/// Hard link fault: recompute tables, re-install, traffic still delivers.
#[test]
fn fault_reroute_delivers_traffic() {
    let cfg = DnpConfig::shapes_rdt();
    let dims = [4, 2, 2];
    let mut net = topology::torus3d(dims, &cfg, 1 << 16);
    let fault = LinkFault { from: [0, 0, 0], dim: 0, plus: true };
    let tables = recompute_tables(dims, &[fault], &cfg, cfg.n_ports).expect("still connected");
    apply_tables(&mut net, tables);

    let nodes = dnp_slots(&net);
    let slots: Vec<usize> = nodes.iter().map(|&(i, _)| i).collect();
    traffic::setup_buffers(&mut net, &slots);
    // All-pairs after reroute. NOTE: the dead channel still exists in the
    // arena but no table points at it.
    let mut plan = Vec::new();
    for (slot, &(node, _)) in nodes.iter().enumerate() {
        for &(_, peer) in nodes.iter() {
            if peer == nodes[slot].1 {
                continue;
            }
            plan.push(traffic::Planned {
                node,
                at: 0,
                cmd: Command::put(traffic::TX_BASE, peer, traffic::rx_addr(slot), 4)
                    .with_tag(0),
            });
        }
    }
    let total = plan.len() as u64;
    let mut feeder = traffic::Feeder::new(plan);
    traffic::run_plan(&mut net, &mut feeder, 5_000_000).expect("rerouted traffic drains");
    assert_eq!(net.traces.delivered, total);
    // The faulted wire must be silent.
    let dead = net
        .chans
        .iter()
        .filter(|(_, c)| c.words_sent == 0)
        .count();
    assert!(dead >= 2, "the two dead directions never carried a word");
}

/// BER injection: payloads corrupt (flagged via CQ), envelopes survive,
/// everything still delivers (paper Sec. II-C / III-A.2).
#[test]
fn ber_injection_flags_but_delivers() {
    let mut cfg = DnpConfig::shapes_rdt();
    cfg.serdes.ber_per_word = 0.02;
    let mut net = topology::two_tiles_offchip(&cfg, 1 << 16);
    let fmt = AddrFormat::Torus3D { dims: [2, 1, 1] };
    let dst = fmt.encode(&[1, 0, 0]);
    net.dnp_mut(1).register_buffer(0x4000, 0x4000, 0);
    for i in 0..20 {
        net.issue(0, Command::put(0x40, dst, 0x4000, 128).with_tag(i));
    }
    net.run_until_idle(10_000_000).expect("BER traffic drains");
    assert_eq!(net.traces.delivered, 20, "no packet may be dropped");
    assert!(
        net.traces.corrupt_packets > 0,
        "2% word BER over 20x128 words must corrupt something"
    );
    // CQ on the receiving tile carries CorruptPayload events.
    let dnp1 = net.dnp(1);
    let mut rd = CqReader::new(dnp1.cq.base(), cfg.cq_len);
    let mut kinds = Vec::new();
    while let Some(ev) = rd.poll(&dnp1.mem, &dnp1.cq) {
        kinds.push(ev.kind);
    }
    assert!(kinds.contains(&EventKind::PacketWritten));
    assert!(kinds.contains(&EventKind::CorruptPayload));
}

/// The CQ tells software exactly what happened, in order, on a clean run.
#[test]
fn completion_queue_event_stream() {
    let cfg = DnpConfig::shapes_rdt();
    let mut net = topology::two_tiles_offchip(&cfg, 1 << 16);
    let fmt = AddrFormat::Torus3D { dims: [2, 1, 1] };
    let dst = fmt.encode(&[1, 0, 0]);
    net.dnp_mut(1).register_buffer(0x4000, 256, 0);
    net.issue(0, Command::put(0x40, dst, 0x4000, 4).with_tag(77));
    net.run_until_idle(100_000).unwrap();

    // Sender CQ: CmdDone with our tag.
    let d0 = net.dnp(0);
    let mut rd = CqReader::new(d0.cq.base(), cfg.cq_len);
    let ev = rd.poll(&d0.mem, &d0.cq).expect("sender event");
    assert_eq!(ev.kind, EventKind::CmdDone);
    assert_eq!(ev.len_or_tag, 77);

    // Receiver CQ: PacketWritten with the landing address.
    let d1 = net.dnp(1);
    let mut rd = CqReader::new(d1.cq.base(), cfg.cq_len);
    let ev = rd.poll(&d1.mem, &d1.cq).expect("receiver event");
    assert_eq!(ev.kind, EventKind::PacketWritten);
    assert_eq!(ev.addr, 0x4000);
    assert_eq!(ev.len_or_tag, 4);
}

/// Arbitration policies: all three drain the same contended workload.
#[test]
fn arbitration_policies_all_drain() {
    for arb in [
        ArbPolicy::RoundRobin,
        ArbPolicy::FixedPriority,
        ArbPolicy::LeastRecentlyServed,
    ] {
        let mut cfg = DnpConfig::shapes_rdt();
        cfg.arb = arb;
        let mut net = topology::torus3d([2, 2, 2], &cfg, 1 << 16);
        let nodes = dnp_slots(&net);
        let slots: Vec<usize> = nodes.iter().map(|&(i, _)| i).collect();
        traffic::setup_buffers(&mut net, &slots);
        let plan = traffic::hotspot(&nodes, 0, 4, 32);
        let total = plan.len() as u64;
        let mut feeder = traffic::Feeder::new(plan);
        traffic::run_plan(&mut net, &mut feeder, 5_000_000)
            .unwrap_or_else(|| panic!("{arb:?} wedged"));
        assert_eq!(net.traces.delivered, total, "{arb:?}");
    }
}

/// Big-payload fragmentation across the network: a 1000-word PUT arrives
/// intact (4 wire packets reassembled in order at the same buffer).
#[test]
fn fragmented_put_reassembles() {
    let cfg = DnpConfig::shapes_rdt();
    let mut net = topology::two_tiles_offchip(&cfg, 1 << 16);
    let fmt = AddrFormat::Torus3D { dims: [2, 1, 1] };
    let dst = fmt.encode(&[1, 0, 0]);
    let data: Vec<u32> = (0..1000).map(|i| i * 3 + 1).collect();
    net.dnp_mut(0).mem.write_slice(0x1000, &data);
    net.dnp_mut(1).register_buffer(0x4000, 1024, 0);
    net.issue(0, Command::put(0x1000, dst, 0x4000, 1000).with_tag(5));
    net.run_until_idle(1_000_000).expect("fragmented PUT drains");
    assert_eq!(net.traces.delivered, 4, "1000 words = 4 packets");
    assert_eq!(net.dnp(1).mem.read_slice(0x4000, 1000), &data[..]);
}

/// Latency measured with tracing ON equals the counters with tracing OFF
/// (tracing must not perturb simulated behaviour).
#[test]
fn tracing_does_not_perturb_simulation() {
    let run = |trace: bool| -> u64 {
        let cfg = DnpConfig::shapes_rdt();
        let mut net = topology::torus3d([2, 2, 2], &cfg, 1 << 16);
        net.traces.enabled = trace;
        let slots: Vec<usize> = (0..8).collect();
        traffic::setup_buffers(&mut net, &slots);
        let mut feeder = traffic::Feeder::new(traffic::halo_exchange_3d([2, 2, 2], 64));
        traffic::run_plan(&mut net, &mut feeder, 1_000_000).expect("drains")
    };
    assert_eq!(run(true), run(false));
}

/// Smoke over the metrics helpers on a live net.
#[test]
fn metrics_helpers_report() {
    let cfg = DnpConfig::shapes_rdt();
    let mut net = topology::two_tiles_offchip(&cfg, 1 << 16);
    let fmt = AddrFormat::Torus3D { dims: [2, 1, 1] };
    let dst = fmt.encode(&[1, 0, 0]);
    net.dnp_mut(1).register_buffer(0x4000, 512, 0);
    net.issue(0, Command::put(0x40, dst, 0x4000, 256).with_tag(1));
    net.run_until_idle(1_000_000).unwrap();
    let elapsed = net.cycle;
    assert!(metrics::delivered_gbs(&net, elapsed, 500.0) > 0.0);
    assert!(metrics::peak_channel_bits_per_cycle(&net, elapsed) > 0.0);
    assert!(metrics::intra_tile_bw_bits_per_cycle(&net, 1, elapsed) > 0.0);
    let util = metrics::channel_utilization(&net, elapsed);
    assert!(util.iter().any(|&(_, u)| u > 0.0));
    assert!(util.iter().all(|&(_, u)| u <= 1.0 + 1e-9));
}
