//! Property-based tests (hand-rolled harness — the image has no proptest).
//!
//! Each property runs many randomized cases from a seeded [`SplitMix64`];
//! failures carry the case index so they replay deterministically.

use dnp::config::{DnpConfig, RouteOrder};
use dnp::fault::{recompute_hybrid_tables_with, HierLinkFault};
use dnp::metrics::{adaptive_decision_report, sharded_totals};
use dnp::packet::{AddrFormat, DnpAddr, Fragmenter, MAX_PAYLOAD_WORDS};
use dnp::rdma::Command;
use dnp::route::{GatewayMap, OutSel, Router, TorusRouter};
use dnp::sim::ShardedNet;
use dnp::util::SplitMix64;
use dnp::{topology, traffic, Net};

/// Property: on any random torus, with any coordinate priority, every
/// (src, dst) pair is delivered in exactly the sum of per-ring minimal
/// distances, and the VC class never exceeds 1.
#[test]
fn prop_torus_routing_delivers_minimally() {
    let mut rng = SplitMix64::new(0xAB70);
    for case in 0..200 {
        let dims = [
            rng.range(1, 5) as u32,
            rng.range(1, 5) as u32,
            rng.range(1, 5) as u32,
        ];
        let order = *rng.pick(&RouteOrder::all());
        let fmt = AddrFormat::Torus3D { dims };
        let n = dims.iter().product::<u32>();
        if n < 2 {
            continue;
        }
        let coords =
            |i: u32| [i % dims[0], (i / dims[0]) % dims[1], i / (dims[0] * dims[1])];
        let s = coords(rng.below(n as u64) as u32);
        let d = coords(rng.below(n as u64) as u32);
        let src = fmt.encode(&s);
        let dst = fmt.encode(&d);
        let mut cur = s;
        let mut vc = 0u8;
        let mut hops = 0u32;
        loop {
            let r = TorusRouter::new(fmt.encode(&cur), dims, order, 0);
            let dec = r.decide(src, dst, vc);
            match dec.out {
                OutSel::Local => break,
                OutSel::Port(p) => {
                    vc = dec.vc;
                    assert!(vc <= 1, "case {case}: vc {vc} out of range");
                    let dim = p / 2;
                    let k = dims[dim];
                    cur[dim] = if p % 2 == 0 {
                        (cur[dim] + 1) % k
                    } else {
                        (cur[dim] + k - 1) % k
                    };
                    hops += 1;
                    assert!(hops <= 12, "case {case}: dims {dims:?} {s:?}->{d:?} livelock");
                }
            }
        }
        let mut expect = 0u32;
        for dim in 0..3 {
            let k = dims[dim];
            let fwd = (d[dim] + k - s[dim]) % k;
            expect += fwd.min(k - fwd);
        }
        assert_eq!(hops, expect, "case {case}: non-minimal path");
    }
}

/// Property: random mixtures of PUT/SEND/GET traffic on random small tori
/// always drain (no deadlock), conserve packet counts, never corrupt at
/// zero BER and never leak store slots.
#[test]
fn prop_random_traffic_conservation() {
    let mut rng = SplitMix64::new(0xBEEF);
    for case in 0..12 {
        let dims_pool = [[2u32, 2, 2], [3, 2, 1], [4, 2, 1], [2, 3, 2]];
        let dims = *rng.pick(&dims_pool);
        let cfg = DnpConfig::shapes_rdt();
        let mut net = topology::torus3d(dims, &cfg, 1 << 16);
        let n = net.nodes.len();
        let slots: Vec<usize> = (0..n).collect();
        traffic::setup_buffers(&mut net, &slots);
        let fmt = AddrFormat::Torus3D { dims };
        let coords =
            |i: u32| [i % dims[0], (i / dims[0]) % dims[1], i / (dims[0] * dims[1])];
        let addrs: Vec<DnpAddr> = (0..n as u32).map(|i| fmt.encode(&coords(i))).collect();

        let mut plan = Vec::new();
        let mut expected = 0u64;
        for slot in 0..n {
            for c in 0..rng.range(1, 6) {
                let mut peer = rng.below(n as u64) as usize;
                if peer == slot {
                    peer = (peer + 1) % n;
                }
                let len = rng.range(1, 300) as u32; // crosses the 256 boundary
                let kind = rng.below(3);
                let (cmd, deliveries) = match kind {
                    0 => {
                        let l = len.min(traffic::RX_WINDOW);
                        (
                            Command::put(traffic::TX_BASE, addrs[peer], traffic::rx_addr(slot), l),
                            Fragmenter::packet_count(l) as u64,
                        )
                    }
                    1 => {
                        let l = len.min(64);
                        (
                            Command::send(traffic::TX_BASE, addrs[peer], l),
                            Fragmenter::packet_count(l) as u64,
                        )
                    }
                    _ => {
                        let l = len.min(traffic::RX_WINDOW);
                        (
                            Command::get(
                                addrs[peer],
                                traffic::TX_BASE,
                                addrs[slot],
                                traffic::rx_addr(peer),
                                l,
                            ),
                            // Request packet + response fragments.
                            1 + Fragmenter::packet_count(l) as u64,
                        )
                    }
                };
                expected += deliveries;
                plan.push(traffic::Planned {
                    node: slot,
                    at: rng.below(500),
                    cmd: cmd.with_tag((slot * 100 + c as usize) as u32),
                });
            }
        }
        let mut feeder = traffic::Feeder::new(plan);
        traffic::run_plan(&mut net, &mut feeder, 10_000_000)
            .unwrap_or_else(|| panic!("case {case}: traffic wedged (dims {dims:?})"));
        assert_eq!(net.traces.delivered, expected, "case {case}: conservation");
        assert_eq!(net.traces.corrupt_packets, 0, "case {case}: zero BER");
        assert_eq!(net.store.live(), 0, "case {case}: packet leak");
    }
}

/// Property: fragmentation partitions any length exactly, in order, with
/// all fragments <= 256 words and contiguous destination addresses.
#[test]
fn prop_fragmenter_partition() {
    let mut rng = SplitMix64::new(77);
    for case in 0..500 {
        let len = rng.below(5000) as u32;
        let dst = rng.next_u32() & 0xFFFF;
        let frags: Vec<_> = Fragmenter::new(len, dst).collect();
        assert_eq!(frags.len() as u32, Fragmenter::packet_count(len), "case {case}");
        let mut off = 0u32;
        for f in &frags {
            assert_eq!(f.offset, off, "case {case}: contiguous");
            assert_eq!(f.dst_mem, dst.wrapping_add(off), "case {case}: dst walks");
            assert!(f.len as usize <= MAX_PAYLOAD_WORDS, "case {case}");
            off += f.len;
        }
        assert_eq!(off, len, "case {case}: full coverage");
    }
}

/// Property: random data PUT between random nodes arrives bit-exact (the
/// end-to-end memory-to-memory integrity invariant).
#[test]
fn prop_put_data_integrity() {
    let mut rng = SplitMix64::new(0xDA7A);
    let cfg = DnpConfig::shapes_rdt();
    for case in 0..10 {
        let mut net = topology::torus3d([2, 2, 2], &cfg, 1 << 16);
        let fmt = AddrFormat::Torus3D { dims: [2, 2, 2] };
        let s = rng.below(8) as usize;
        let mut d = rng.below(8) as usize;
        if d == s {
            d = (d + 1) % 8;
        }
        let len = rng.range(1, 600) as u32;
        let data: Vec<u32> = (0..len).map(|_| rng.next_u32()).collect();
        net.dnp_mut(s).mem.write_slice(0x1000, &data);
        net.dnp_mut(d).register_buffer(0x8000, 1024, 0);
        let dc = [d as u32 % 2, (d as u32 / 2) % 2, d as u32 / 4];
        net.issue(
            s,
            Command::put(0x1000, fmt.encode(&dc), 0x8000, len).with_tag(1),
        );
        net.run_until_idle(1_000_000)
            .unwrap_or_else(|| panic!("case {case} wedged"));
        assert_eq!(
            net.dnp(d).mem.read_slice(0x8000, len),
            &data[..],
            "case {case}: s={s} d={d} len={len}"
        );
    }
}

/// Property (ISSUE 9): random adaptive hybrid systems with random PUT
/// plans and one random killed SerDes lane — UGAL-lite never loses a
/// packet (exact delivery conservation through recovery tables), and the
/// dead wires carry exactly zero words: a stale lane stamp can never
/// steer traffic onto a killed cable, because recovered `TableRouter`s
/// ignore stamps by construction.
#[test]
fn prop_adaptive_random_faulted_traffic_no_loss_dead_wires_silent() {
    let mut rng = SplitMix64::new(0xADA9);
    let tiles: [u32; 2] = [2, 2];
    let chips_pool = [[2u32, 2, 1], [2, 2, 2], [3, 2, 1], [4, 1, 1]];
    let cfg = DnpConfig::hybrid();
    let mut recovered = 0usize;
    for case in 0..8 {
        let chips = *rng.pick(&chips_pool);
        let lanes = rng.range(2, 4) as usize; // <= the 4 gateway tiles
        let threshold = rng.range(0, 9) as u32;
        let gmap = GatewayMap::adaptive_with(tiles, lanes, threshold);
        let fmt = AddrFormat::Hybrid { chip_dims: chips, tile_dims: tiles };
        let n = fmt.node_count() as usize;

        let mut plan = Vec::new();
        let mut expected = 0u64;
        for slot in 0..n {
            for c in 0..rng.range(1, 4) {
                let mut peer = rng.below(n as u64) as usize;
                if peer == slot {
                    peer = (peer + 1) % n;
                }
                let len = rng.range(1, 200) as u32;
                expected += u64::from(Fragmenter::packet_count(len));
                let dst = fmt.encode(&traffic::hybrid_coords(chips, tiles, peer));
                plan.push(traffic::Planned {
                    node: slot,
                    at: rng.below(400),
                    cmd: Command::put(traffic::TX_BASE, dst, traffic::rx_addr(slot), len)
                        .with_tag((slot * 100 + c as usize) as u32),
                });
            }
        }

        // One random owned `+` cable of a live ring dimension dies.
        let live: Vec<usize> = (0..3).filter(|&d| chips[d] >= 2).collect();
        let dim = *rng.pick(&live);
        let ci = rng.below(chips.iter().product::<u32>() as u64) as u32;
        let chip = [ci % chips[0], (ci / chips[0]) % chips[1], ci / (chips[0] * chips[1])];
        let lane = rng.below(lanes as u64) as usize;
        let dead = HierLinkFault::SerdesLane { chip, dim, plus: true, lane };
        let tables = match recompute_hybrid_tables_with(chips, &gmap, &[dead], &cfg) {
            Ok(t) => t,
            Err(e) => {
                // A sound typed refusal; the property only requires that
                // most single-fault cases recover.
                println!("case {case}: {dead:?} refused ({e:?})");
                continue;
            }
        };

        let workers = rng.range(1, 4) as usize;
        let mut snet = ShardedNet::hybrid_with(chips, &gmap, &cfg, 1 << 16, workers)
            .expect("uniform SHAPES links shard cleanly");
        traffic::setup_buffers_sharded(&mut snet);
        snet.apply_tables(tables);
        let elapsed = traffic::run_plan_sharded(&mut snet, plan, 10_000_000);
        assert!(elapsed.is_some(), "case {case}: chips {chips:?} lanes {lanes} wedged");
        assert_eq!(
            sharded_totals(&snet).delivered,
            expected,
            "case {case}: chips {chips:?} lanes {lanes} lost packets"
        );
        for link in snet.links_of(&dead) {
            assert_eq!(
                snet.link_words_sent(link),
                0,
                "case {case}: dead wire {link} carried flits"
            );
        }
        recovered += 1;
    }
    assert!(recovered >= 4, "too few recoverable single-fault cases ({recovered}/8)");
}

/// Property (ISSUE 9): per-flow lane freezing + minimal-pick degeneracy.
/// On an otherwise idle fabric every UGAL-lite pick is minimal — the
/// strict-improvement rule keeps the hash lane even at threshold 0 — so
/// a single random cross-chip PUT under `Adaptive` must be
/// indistinguishable from the same PUT under `DstHash` with the same
/// lane count: identical drain cycle, delivery count and destination
/// memory. The stream's stamp is chosen once at injection, so the whole
/// multi-fragment wormhole rides one lane per dimension for its entire
/// lifetime (any mid-flow lane flip would desynchronize the two runs).
#[test]
fn prop_adaptive_idle_fabric_matches_dst_hash() {
    let mut rng = SplitMix64::new(0x1A9E);
    let tiles: [u32; 2] = [2, 2];
    let cfg = DnpConfig::hybrid();
    for case in 0..10 {
        let chips = *rng.pick(&[[2u32, 2, 1], [2, 2, 2], [3, 2, 1]]);
        let lanes = rng.range(2, 4) as usize; // <= the 4 gateway tiles
        let threshold = rng.range(0, 6) as u32;
        let fmt = AddrFormat::Hybrid { chip_dims: chips, tile_dims: tiles };
        let n = fmt.node_count() as usize;
        let ntiles = (tiles[0] * tiles[1]) as usize;
        let s = rng.below(n as u64) as usize;
        let mut d = rng.below(n as u64) as usize;
        if d / ntiles == s / ntiles {
            d = (d + ntiles) % n; // force a cross-chip flow
        }
        let len = rng.range(1, 700) as u32; // multi-fragment streams too

        let run = |gmap: &GatewayMap| {
            let mut net = topology::hybrid_torus_mesh_with(chips, gmap, &cfg, 1 << 16);
            let slots: Vec<usize> = (0..n).collect();
            traffic::setup_buffers(&mut net, &slots);
            let dst = fmt.encode(&traffic::hybrid_coords(chips, tiles, d));
            net.issue(
                s,
                Command::put(traffic::TX_BASE, dst, traffic::rx_addr(s), len).with_tag(7),
            );
            let elapsed = net.run_until_idle(2_000_000);
            let mem = net.dnp(d).mem.read_slice(traffic::rx_addr(s), len).to_vec();
            let rep = adaptive_decision_report(&net);
            (elapsed, net.traces.delivered, mem, rep)
        };
        let ada = run(&GatewayMap::adaptive_with(tiles, lanes, threshold));
        let hash = run(&GatewayMap::dst_hash(tiles, lanes));
        let tag = format!("case {case}: chips {chips:?} lanes {lanes} t={threshold} {s}->{d}");
        assert!(ada.0.is_some(), "{tag}: adaptive run wedged");
        assert_eq!(ada.0, hash.0, "{tag}: drain cycle diverged");
        assert_eq!(ada.1, hash.1, "{tag}: deliveries diverged");
        assert_eq!(ada.2, hash.2, "{tag}: destination memory diverged");
        // The DstHash net has no injector; the adaptive net made exactly
        // one pick (the single stream) and it was minimal.
        assert_eq!(hash.3.decisions(), 0, "{tag}: DstHash must not record picks");
        assert_eq!((ada.3.minimal, ada.3.alternate), (1, 0), "{tag}: {:?}", ada.3);
    }
}

/// Property: the config parser round-trips valid settings and rejects
/// junk without panicking.
#[test]
fn prop_config_parse_fuzz() {
    let mut rng = SplitMix64::new(0xC0FF);
    for case in 0..100 {
        let l = rng.range(1, 4);
        let n = rng.range(1, 4);
        let m = rng.range(1, 8);
        let factor = [4u32, 8, 16, 32][rng.below(4) as usize];
        let text =
            format!("l_ports = {l}\nn_ports = {n}\nm_ports = {m}\nserdes.factor = {factor}\n");
        let c = dnp::config::parse_config(&text, DnpConfig::default())
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(c.l_ports as u64, l);
        assert_eq!(c.m_ports as u64, m);
        assert_eq!(c.serdes.factor, factor);
    }
    for _ in 0..300 {
        let len = rng.below(40) as usize;
        let soup: String = (0..len).map(|_| (rng.below(94) as u8 + 32) as char).collect();
        let _ = dnp::config::parse_config(&soup, DnpConfig::default()); // must not panic
    }
}

/// Property: simulation determinism — identical plans give identical
/// cycle counts, deliveries and word counts.
#[test]
fn prop_simulation_determinism() {
    let run = |seed: u64| -> (u64, u64, u64) {
        let cfg = DnpConfig::shapes_rdt();
        let mut net = topology::torus3d([2, 2, 2], &cfg, 1 << 16);
        let nodes: Vec<(usize, DnpAddr)> = net
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| n.as_dnp().map(|d| (i, d.addr)))
            .collect();
        let slots: Vec<usize> = (0..8).collect();
        traffic::setup_buffers(&mut net, &slots);
        let plan = traffic::uniform_random(&nodes, 8, 16, 10, seed);
        let mut feeder = traffic::Feeder::new(plan);
        let cycles = traffic::run_plan(&mut net, &mut feeder, 5_000_000).unwrap();
        (cycles, net.traces.delivered, net.traces.delivered_words)
    };
    for seed in [1u64, 42, 0xFFFF_FFFF] {
        assert_eq!(run(seed), run(seed), "seed {seed}");
    }
    assert_ne!(run(1), run(2), "different seeds must differ");
}

/// Property: under BER injection sweeps, every packet still arrives (no
/// drops ever) and the corruption rate tracks the injected rate.
#[test]
fn prop_ber_sweep_no_drops() {
    for (case, ber) in [0.0, 0.001, 0.01, 0.05].into_iter().enumerate() {
        let mut cfg = DnpConfig::shapes_rdt();
        cfg.serdes.ber_per_word = ber;
        let mut net = topology::two_tiles_offchip(&cfg, 1 << 16);
        let fmt = AddrFormat::Torus3D { dims: [2, 1, 1] };
        net.dnp_mut(1).register_buffer(0x4000, 0x4000, 0);
        let count = 30u32;
        for i in 0..count {
            net.issue(
                0,
                Command::put(0x1000, fmt.encode(&[1, 0, 0]), 0x4000, 64).with_tag(i),
            );
        }
        net.run_until_idle(20_000_000)
            .unwrap_or_else(|| panic!("case {case} (ber={ber}) wedged"));
        assert_eq!(net.traces.delivered, count as u64, "case {case}: drops");
        if ber == 0.0 {
            assert_eq!(net.traces.corrupt_packets, 0, "case {case}");
        }
        if ber >= 0.01 {
            assert!(net.traces.corrupt_packets > 0, "case {case}: ber={ber}");
        }
    }
}
