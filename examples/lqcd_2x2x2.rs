//! END-TO-END DRIVER — the paper's Sec. IV benchmark, all layers composed:
//!
//! * L1: the Pallas SU(3) kernel (inside the AOT artifact),
//! * L2: the JAX Dslash model (AOT-lowered to `artifacts/dslash_4.hlo.txt`),
//! * runtime: PJRT CPU client executing the artifact as each tile's "DSP",
//! * L3: the cycle-accurate DNP-Net carrying every halo byte over RDMA PUT
//!   on a 2×2×2 3D torus of SHAPES RDT tiles.
//!
//! Run: `make artifacts && cargo run --release --example lqcd_2x2x2 [steps]`
//!
//! Prints the per-step Dslash norm (a power-iteration observable — it
//! converges to the operator's largest singular value), the simulated
//! halo-exchange cycles, and the comm/compute balance; cross-checks step
//! results against the pure-rust oracle. Recorded in EXPERIMENTS.md §E8.

use dnp::lqcd::run_lqcd_2x2x2;

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);

    println!("== LQCD on 8 RDTs, 2x2x2 3D torus (paper Sec. IV) ==");
    println!("-- compute backend: PJRT (JAX/Pallas artifact dslash_4) --");
    let pjrt = match run_lqcd_2x2x2(steps, [4, 4, 4], true) {
        Ok(r) => r,
        Err(e) => {
            // Default builds carry no PJRT (the `pjrt` feature gates the
            // xla dependency); fall back to the pure-rust oracle so the
            // example still demonstrates the full simulated exchange.
            println!("PJRT unavailable ({e:#}); running oracle backend only\n");
            let oracle =
                run_lqcd_2x2x2(steps, [4, 4, 4], false).expect("oracle run");
            println!("{}\n", oracle.summary());
            return;
        }
    };
    println!("{}\n", pjrt.summary());

    println!("-- cross-check: pure-rust oracle backend --");
    let oracle = run_lqcd_2x2x2(steps, [4, 4, 4], false).expect("oracle run");
    println!("{}\n", oracle.summary());

    let mut max_rel = 0.0f64;
    for (a, b) in pjrt.norms.iter().zip(oracle.norms.iter()) {
        max_rel = max_rel.max(((a - b).abs() / b.abs().max(1e-30)) as f64);
    }
    assert_eq!(pjrt.halo_cycles, oracle.halo_cycles, "network must be identical");
    assert!(max_rel < 1e-3, "PJRT vs oracle diverged: {max_rel}");
    println!("PJRT vs oracle: max relative norm deviation {max_rel:.2e}  ✓");

    // Convergence of the power iteration (physics sanity).
    if steps >= 4 {
        let n = pjrt.norms.len();
        let tail_drift = ((pjrt.norms[n - 1] - pjrt.norms[n - 2]) / pjrt.norms[n - 1]).abs();
        println!("power-iteration tail drift: {tail_drift:.3e} (converging)");
    }
}
