//! §Shard-scale smoke: the 512-chip system under asymmetric load.
//!
//! An 8×8×8 chip torus of 2×2 tile meshes — 512 shards, 2048 DNPs —
//! where only the 8 chips of one x-axis row send (each tile PUTs to its
//! antipodal chip) and the other 504 chips sit idle. The load is the
//! worst case for the windowed-barrier runner (every shard pays every
//! global window) and the best case for the per-link conservative
//! clocks (idle shards run ahead at their own pace), so the sweep below
//! is the headline scalability comparison of EXPERIMENTS.md
//! §Shard-scale. Every (mode × workers) run must stay bit-exact with
//! every other at the fixed budget; the `[shard-scale]` rows are
//! harvested by CI into the experiments summary.
//!
//! Run: `cargo run --release --example shard_scale [max_workers]`
//! (default sweep: 1, 2, 4, 8, 16 workers in both modes).

use std::time::Instant;

use dnp::config::DnpConfig;
use dnp::metrics::{scheduler_totals, sharded_totals, NetTotals};
use dnp::packet::AddrFormat;
use dnp::rdma::Command;
use dnp::sim::{ParallelMode, ShardedNet};
use dnp::traffic::{self, Planned};

const CHIPS: [u32; 3] = [8, 8, 8];
const TILES: [u32; 2] = [2, 2];
const MEM: usize = 1 << 15;
const BUDGET: u64 = 10_000_000;

/// Asymmetric antipodal load: row (y=0, z=0) sends, everyone else idles.
/// Per-sender RX windows are infeasible at 2048 nodes, so every flow
/// lands in one shared `0x4000` window — this is a scheduler workload,
/// not a payload check (the equivalence suite owns those).
fn scale_plan() -> Vec<Planned> {
    let fmt = AddrFormat::Hybrid { chip_dims: CHIPS, tile_dims: TILES };
    let tiles = (TILES[0] * TILES[1]) as usize;
    let mut plan = Vec::new();
    for x in 0..CHIPS[0] {
        for t in 0..tiles {
            let tc = [t as u32 % TILES[0], t as u32 / TILES[0]];
            let node = traffic::hybrid_node_index(CHIPS, TILES, [x, 0, 0], tc);
            let dst = fmt.encode(&[(x + 4) % CHIPS[0], 4, 4, tc[0], tc[1]]);
            for i in 0..4u64 {
                plan.push(Planned {
                    node,
                    at: i * 97 + u64::from(x) * 11,
                    cmd: Command::put(0x1000, dst, 0x4000, 32)
                        .with_tag((node as u32) * 8 + i as u32),
                });
            }
        }
    }
    plan
}

fn main() {
    let max_workers: usize = std::env::args()
        .nth(1)
        .map(|a| a.parse().expect("max_workers must be a number"))
        .unwrap_or(16);
    let cfg = DnpConfig::hybrid();
    let n = (CHIPS.iter().product::<u32>() * TILES.iter().product::<u32>()) as usize;
    let nchips = CHIPS.iter().product::<u32>();
    println!(
        "shard-scale: {}x{}x{} chips of {}x{} tiles = {n} DNPs, {nchips} shards, \
         budget {BUDGET} cycles",
        CHIPS[0], CHIPS[1], CHIPS[2], TILES[0], TILES[1],
    );

    // (elapsed, totals) of the first run: every later (mode × workers)
    // combination must reproduce it exactly at the fixed budget.
    let mut reference: Option<(Option<u64>, NetTotals)> = None;
    for mode in [ParallelMode::Barrier, ParallelMode::LinkClock] {
        for workers in [1usize, 2, 4, 8, 16] {
            if workers > max_workers {
                continue;
            }
            let mut snet =
                ShardedNet::hybrid(CHIPS, TILES, &cfg, MEM, workers).expect("uniform links");
            snet.set_parallel_mode(mode);
            snet.set_tracing(false);
            for i in 0..n {
                snet.dnp_mut(i)
                    .register_buffer(0x4000, traffic::RX_WINDOW, 0)
                    .expect("LUT capacity (one shared window)");
            }
            let t0 = Instant::now();
            let elapsed = traffic::run_plan_sharded(&mut snet, scale_plan(), BUDGET);
            let wall = t0.elapsed().as_secs_f64();
            let totals = sharded_totals(&snet);
            let sched = scheduler_totals(&snet);
            let cycles = elapsed.unwrap_or(BUDGET);
            println!(
                "[shard-scale] mode={mode:?} workers={workers} cycles={cycles} \
                 delivered={} wall={wall:.3}s Mcycles/s={:.2} horizon={} rounds={} \
                 busy={} null={} stalls={} util={:.3}",
                totals.delivered,
                cycles as f64 / wall / 1e6,
                snet.horizon(),
                sched.rounds,
                sched.busy_windows,
                sched.null_windows,
                sched.stalls,
                sched.utilization(),
            );
            assert!(elapsed.is_some(), "the load must drain inside the budget");
            assert!(totals.delivered > 0, "the senders must deliver");
            match &reference {
                None => reference = Some((elapsed, totals)),
                Some((re, rt)) => {
                    assert_eq!(*re, elapsed, "mode={mode:?} w{workers}: drain cycle diverged");
                    assert_eq!(*rt, totals, "mode={mode:?} w{workers}: totals diverged");
                }
            }
        }
    }
    println!("[shard-scale] every mode x worker count bit-exact at the fixed budget: OK");
}
