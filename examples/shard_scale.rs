//! §Shard-scale / §Shard-steal smoke: the 512-chip system under
//! asymmetric load.
//!
//! An 8×8×8 chip torus of 2×2 tile meshes — 512 shards, 2048 DNPs —
//! under one of two adversarial scenarios:
//!
//! * **row** (`[shard-scale]` rows, EXPERIMENTS.md §Shard-scale): only
//!   the 8 chips of one x-axis row send, each tile PUTting to its
//!   antipodal chip. Worst case for the windowed-barrier runner (every
//!   shard pays every global window), best case for per-link
//!   conservative clocks (idle shards run ahead at their own pace).
//! * **hotspot** (`[shard-steal]` rows, EXPERIMENTS.md §Shard-steal):
//!   the same 8 sender chips — a CONTIGUOUS chip-index range, so static
//!   placement parks them all on worker 0 at w8 — funnel every PUT into
//!   the single victim chip (4,4,4) while the other 503 chips idle.
//!   Static placement provably wastes cores here (most workers own
//!   nothing but clock spinning); the work-stealing runner migrates the
//!   hot tokens to idle workers, which is exactly what the
//!   LinkClock-vs-WorkSteal wall-clock comparison at the bottom
//!   measures.
//!
//! Every (mode × workers) run must stay bit-exact with every other at
//! the fixed budget; the `[shard-scale]`/`[shard-steal]` rows are
//! harvested by CI into the experiments summary.
//!
//! Run: `cargo run --release --example shard_scale [max_workers] [mode] [scenario]`
//! with mode `barrier|linkclock|worksteal|all` (default `all`) and
//! scenario `row|hotspot` (default `row`). Default sweep: 1, 2, 4, 8,
//! 16 workers.

use std::time::Instant;

use dnp::config::DnpConfig;
use dnp::metrics::{scheduler_totals, sharded_totals, steal_report, NetTotals};
use dnp::packet::AddrFormat;
use dnp::rdma::Command;
use dnp::sim::{ParallelMode, ShardedNet};
use dnp::traffic::{self, Planned};

const CHIPS: [u32; 3] = [8, 8, 8];
const TILES: [u32; 2] = [2, 2];
const MEM: usize = 1 << 15;
const BUDGET: u64 = 10_000_000;
const SWEEP: [usize; 5] = [1, 2, 4, 8, 16];

/// Asymmetric antipodal load: row (y=0, z=0) sends, everyone else idles.
/// Per-sender RX windows are infeasible at 2048 nodes, so every flow
/// lands in one shared `0x4000` window — this is a scheduler workload,
/// not a payload check (the equivalence suite owns those).
fn scale_plan() -> Vec<Planned> {
    let fmt = AddrFormat::Hybrid { chip_dims: CHIPS, tile_dims: TILES };
    let tiles = (TILES[0] * TILES[1]) as usize;
    let mut plan = Vec::new();
    for x in 0..CHIPS[0] {
        for t in 0..tiles {
            let tc = [t as u32 % TILES[0], t as u32 / TILES[0]];
            let node = traffic::hybrid_node_index(CHIPS, TILES, [x, 0, 0], tc);
            let dst = fmt.encode(&[(x + 4) % CHIPS[0], 4, 4, tc[0], tc[1]]);
            for i in 0..4u64 {
                plan.push(Planned {
                    node,
                    at: i * 97 + u64::from(x) * 11,
                    cmd: Command::put(0x1000, dst, 0x4000, 32)
                        .with_tag((node as u32) * 8 + i as u32),
                });
            }
        }
    }
    plan
}

/// Adversarial quiet-chip hotspot: chips (x,0,0) — indices 0..8, one
/// contiguous chunk under static placement — send widely spaced PUTs
/// that ALL land on chip (4,4,4)'s tiles. One victim shard and eight
/// sender shards carry every real step; the remaining 503 shards only
/// spin clocks.
fn hotspot_plan() -> Vec<Planned> {
    let fmt = AddrFormat::Hybrid { chip_dims: CHIPS, tile_dims: TILES };
    let tiles = (TILES[0] * TILES[1]) as usize;
    let mut plan = Vec::new();
    for x in 0..CHIPS[0] {
        for t in 0..tiles {
            let tc = [t as u32 % TILES[0], t as u32 / TILES[0]];
            let node = traffic::hybrid_node_index(CHIPS, TILES, [x, 0, 0], tc);
            let dst = fmt.encode(&[4, 4, 4, tc[0], tc[1]]);
            for i in 0..6u64 {
                plan.push(Planned {
                    node,
                    at: i * 617 + u64::from(x) * 13,
                    cmd: Command::put(0x1000, dst, 0x4000, 24)
                        .with_tag((node as u32) * 8 + i as u32),
                });
            }
        }
    }
    plan
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let max_workers: usize = args
        .first()
        .map(|a| a.parse().expect("max_workers must be a number"))
        .unwrap_or(16);
    let mode_arg = args.get(1).map_or("all", String::as_str);
    let scenario = args.get(2).map_or("row", String::as_str);
    let modes: Vec<ParallelMode> = if mode_arg == "all" {
        vec![ParallelMode::Barrier, ParallelMode::LinkClock, ParallelMode::WorkSteal]
    } else {
        vec![mode_arg.parse().expect("mode must be barrier|linkclock|worksteal|all")]
    };
    let (tag, plan_fn): (&str, fn() -> Vec<Planned>) = match scenario {
        "row" => ("[shard-scale]", scale_plan),
        "hotspot" => ("[shard-steal]", hotspot_plan),
        other => panic!("unknown scenario '{other}' (expected row|hotspot)"),
    };
    let cfg = DnpConfig::hybrid();
    let n = (CHIPS.iter().product::<u32>() * TILES.iter().product::<u32>()) as usize;
    let nchips = CHIPS.iter().product::<u32>();
    println!(
        "shard-scale: {}x{}x{} chips of {}x{} tiles = {n} DNPs, {nchips} shards, \
         scenario {scenario}, budget {BUDGET} cycles",
        CHIPS[0], CHIPS[1], CHIPS[2], TILES[0], TILES[1],
    );

    // (elapsed, totals) of the first run: every later (mode × workers)
    // combination must reproduce it exactly at the fixed budget.
    let mut reference: Option<(Option<u64>, NetTotals)> = None;
    // wall[mode][worker-sweep-slot], for the steal-vs-static compare.
    let mut walls: Vec<Vec<Option<f64>>> = vec![vec![None; SWEEP.len()]; modes.len()];
    for (mi, &mode) in modes.iter().enumerate() {
        for (wi, &workers) in SWEEP.iter().enumerate() {
            if workers > max_workers {
                continue;
            }
            let mut snet =
                ShardedNet::hybrid(CHIPS, TILES, &cfg, MEM, workers).expect("uniform links");
            snet.set_parallel_mode(mode);
            snet.set_tracing(false);
            for i in 0..n {
                snet.dnp_mut(i)
                    .register_buffer(0x4000, traffic::RX_WINDOW, 0)
                    .expect("LUT capacity (one shared window)");
            }
            let t0 = Instant::now();
            let elapsed = traffic::run_plan_sharded(&mut snet, plan_fn(), BUDGET);
            let wall = t0.elapsed().as_secs_f64();
            walls[mi][wi] = Some(wall);
            let totals = sharded_totals(&snet);
            let sched = scheduler_totals(&snet);
            let steal = steal_report(&snet);
            let cycles = elapsed.unwrap_or(BUDGET);
            println!(
                "{tag} mode={mode:?} workers={workers} cycles={cycles} \
                 delivered={} wall={wall:.3}s Mcycles/s={:.2} horizon={} rounds={} \
                 busy={} null={} stalls={} util={:.3} steals={} steal-fails={} \
                 maxq={} hit-rate={:.3}",
                totals.delivered,
                cycles as f64 / wall / 1e6,
                snet.horizon(),
                sched.rounds,
                sched.busy_windows,
                sched.null_windows,
                sched.stalls,
                sched.utilization(),
                steal.steals,
                steal.steal_fails,
                steal.max_queue,
                steal.hit_rate(),
            );
            assert!(elapsed.is_some(), "the load must drain inside the budget");
            assert!(totals.delivered > 0, "the senders must deliver");
            if mode != ParallelMode::WorkSteal {
                assert_eq!(steal.attempts(), 0, "static runners must never steal");
            }
            match &reference {
                None => reference = Some((elapsed, totals)),
                Some((re, rt)) => {
                    assert_eq!(*re, elapsed, "mode={mode:?} w{workers}: drain cycle diverged");
                    assert_eq!(*rt, totals, "mode={mode:?} w{workers}: totals diverged");
                }
            }
        }
    }
    println!("{tag} every mode x worker count bit-exact at the fixed budget: OK");

    // Dynamic-vs-static wall-clock comparison, when both clock runners
    // ran. The hotspot scenario is the headline: static placement parks
    // all eight hot sender shards on one worker, so WorkSteal should win
    // outright at w4+. The assert is deliberately lenient (1.25x) — CI
    // runners have few, noisy cores; the strict per-worker-count
    // acceptance numbers live in EXPERIMENTS.md §Shard-steal, measured
    // via scripts/scalability.sh.
    let lc = modes.iter().position(|&m| m == ParallelMode::LinkClock);
    let ws = modes.iter().position(|&m| m == ParallelMode::WorkSteal);
    if let (Some(lc), Some(ws)) = (lc, ws) {
        for (wi, &workers) in SWEEP.iter().enumerate() {
            let (Some(t_lc), Some(t_ws)) = (walls[lc][wi], walls[ws][wi]) else {
                continue;
            };
            println!(
                "{tag} compare workers={workers} linkclock={t_lc:.3}s worksteal={t_ws:.3}s \
                 speedup={:.2}x",
                t_lc / t_ws,
            );
            if scenario == "hotspot" {
                assert!(
                    t_ws <= t_lc * 1.25,
                    "w{workers}: WorkSteal ({t_ws:.3}s) fell >25% behind LinkClock \
                     ({t_lc:.3}s) on the imbalanced scenario it exists to fix"
                );
            }
        }
    }
}
