//! Architectural exploration — the paper's Sec. III-B story: "thanks to
//! the high level of parametrization offered by the DNP, we were able to
//! propose different solutions for the inter-tile on-chip network".
//!
//! Compares the two explored 8-tile on-chip solutions (MTNoC: Spidergon
//! NoC; MT2D: point-to-point 2D mesh) plus the off-chip 2×2×2 torus, under
//! identical all-pairs PUT traffic, and pairs the performance numbers with
//! the Table-I area/power estimates.
//!
//! Run: `cargo run --release --example topology_explorer`

use dnp::bench::Table;
use dnp::config::DnpConfig;
use dnp::model::{estimate, TechModel};
use dnp::packet::DnpAddr;
use dnp::rdma::Command;
use dnp::util::{median, percentile};
use dnp::{topology, traffic, Net};

fn dnp_slots(net: &Net) -> Vec<(usize, DnpAddr)> {
    net.nodes
        .iter()
        .enumerate()
        .filter_map(|(i, n)| n.as_dnp().map(|d| (i, d.addr)))
        .collect()
}

/// All-pairs PUT of `len` words; returns (drain cycles, per-message
/// latency median, p95) using delivered-packet traces.
fn all_pairs(net: &mut Net, len: u32) -> (u64, f64, f64) {
    let nodes = dnp_slots(net);
    let slots: Vec<usize> = nodes.iter().map(|&(i, _)| i).collect();
    traffic::setup_buffers(net, &slots);
    let mut plan = Vec::new();
    for (slot, &(node, _)) in nodes.iter().enumerate() {
        for (pslot, &(_, peer)) in nodes.iter().enumerate() {
            if pslot == slot {
                continue;
            }
            plan.push(traffic::Planned {
                node,
                at: 0,
                cmd: Command::put(traffic::TX_BASE, peer, traffic::rx_addr(slot), len)
                    .with_tag((slot * 64 + pslot) as u32),
            });
        }
    }
    let mut feeder = traffic::Feeder::new(plan);
    let cycles = traffic::run_plan(net, &mut feeder, 10_000_000).expect("drains");
    let lats: Vec<f64> = net
        .traces
        .pkts
        .values()
        .filter_map(|p| Some((p.delivered? - p.injected?) as f64))
        .collect();
    (cycles, median(&lats), percentile(&lats, 95.0))
}

fn main() {
    let tech = TechModel::default();
    let mut table = Table::new(&[
        "solution",
        "topology",
        "drain cyc",
        "med lat",
        "p95 lat",
        "area mm2",
        "power mW",
    ]);

    {
        let cfg = DnpConfig::mtnoc();
        let mut net = topology::spidergon_chip(8, &cfg, 1 << 16);
        let (cyc, med, p95) = all_pairs(&mut net, 32);
        let e = estimate(&cfg, &tech);
        table.row(&[
            "MTNoC".into(),
            "8-tile ST-Spidergon".into(),
            format!("{cyc}"),
            format!("{med:.0}"),
            format!("{p95:.0}"),
            format!("{:.2}", e.area_mm2),
            format!("{:.0}", e.power_mw),
        ]);
    }
    {
        let cfg = DnpConfig::mt2d();
        let mut net = topology::mesh2d_chip([4, 2], &cfg, 1 << 16);
        let (cyc, med, p95) = all_pairs(&mut net, 32);
        let e = estimate(&cfg, &tech);
        table.row(&[
            "MT2D".into(),
            "8-tile 4x2 mesh".into(),
            format!("{cyc}"),
            format!("{med:.0}"),
            format!("{p95:.0}"),
            format!("{:.2}", e.area_mm2),
            format!("{:.0}", e.power_mw),
        ]);
    }
    {
        let cfg = DnpConfig::shapes_rdt();
        let mut net = topology::torus3d([2, 2, 2], &cfg, 1 << 16);
        let (cyc, med, p95) = all_pairs(&mut net, 32);
        let e = estimate(&cfg, &tech);
        table.row(&[
            "off-chip".into(),
            "2x2x2 torus (SerDes)".into(),
            format!("{cyc}"),
            format!("{med:.0}"),
            format!("{p95:.0}"),
            format!("{:.2}", e.area_mm2),
            format!("{:.0}", e.power_mw),
        ]);
    }
    println!("All-pairs PUT, 32-word payloads, 8 tiles (Fig. 7 exploration):\n");
    table.print();
    println!(
        "\nPaper's trade-off (Sec. IV): MT2D buys direct on-chip ports with\n\
         ~35% more DNP area; MTNoC moves that complexity into the NoC block\n\
         (whose area is NOT included in the Table-I MTNoC figure)."
    );
}
