//! Whole-fabric static verification sweep (ISSUE 7 acceptance).
//!
//! Runs [`dnp::verify`] over the shipped configuration matrix — chip
//! tori `[k,k,1]` for k = 2..=5 plus the full 4×4×4 system, under each
//! gateway policy (`Fixed`, `DimPair`, `DstHash`), healthy and after a
//! fault recovery — and prints one greppable `[verify]` row per cell
//! for the CI experiments-summary artifact (EXPERIMENTS.md §Verify
//! documents the harvest line). No simulation: every row is a static
//! proof obligation (all-pairs delivery over live wires, bounded hops,
//! unified cross-layer CDG acyclicity).
//!
//! Run: `cargo run --release --example verify_fabric`

use dnp::config::DnpConfig;
use dnp::fault::{recompute_hybrid_tables_with, HierLinkFault};
use dnp::route::GatewayMap;
use dnp::verify::{self, FabricReport};

const TILES: [u32; 2] = [2, 2];

fn row(topo: [u32; 3], map: &str, state: &str, rep: &FabricReport) -> bool {
    println!(
        "[verify] topo={}x{}x{} map={map} state={state} pairs={} chans={} edges={} \
         warnings={} errors={} certified={}",
        topo[0],
        topo[1],
        topo[2],
        rep.pairs,
        rep.chans.len(),
        rep.edges.len(),
        rep.warnings,
        rep.errors,
        if rep.is_certified() { "yes" } else { "no" },
    );
    if !rep.is_certified() {
        println!("--- full report for topo={topo:?} map={map} state={state} ---\n{rep}");
    }
    rep.is_certified()
}

fn main() {
    let cfg = DnpConfig::hybrid();
    let maps: [(&str, GatewayMap); 3] = [
        ("fixed", GatewayMap::fixed(TILES)),
        ("dimpair", GatewayMap::dim_pair(TILES)),
        ("dsthash", GatewayMap::dst_hash(TILES, 2)),
    ];
    let mut all_ok = true;

    for topo in [[2, 2, 1], [3, 3, 1], [4, 4, 1], [5, 5, 1], [4, 4, 4]] {
        for (name, gmap) in &maps {
            all_ok &= row(topo, name, "healthy", &verify::check_healthy(topo, gmap, &cfg));

            // Faulted state: kill the first + cable of dimension 0 and
            // one mesh link, recompute, and certify the recovery.
            let lane = (0..gmap.group(0).len())
                .find(|&l| gmap.owns(0, l, 0))
                .expect("some lane owns the + cable");
            let faults = [
                HierLinkFault::SerdesLane { chip: [0, 0, 0], dim: 0, plus: true, lane },
                HierLinkFault::Mesh { chip: [1, 0, 0], tile: [0, 0], dim: 0, plus: true },
            ];
            let tables = recompute_hybrid_tables_with(topo, gmap, &faults, &cfg)
                .expect("the single-cable + mesh scenario is recoverable");
            let rep = verify::check_tables(topo, gmap, &cfg, &faults, &tables);
            all_ok &= row(topo, name, "faulted", &rep);
        }
    }

    assert!(all_ok, "some configuration failed static verification (see reports above)");
    println!("[verify] all configurations certified");
}
