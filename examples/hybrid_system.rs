//! Hybrid system tour — the paper's Fig. 2 composition: a 2×2 off-chip
//! SerDes torus of chips, each chip a 2×2 on-chip mesh of tiles, every
//! tile's DNP serving both regimes through the same crossbar (gateway
//! tiles additionally own the chip's off-chip links).
//!
//! Shows the on-chip vs cross-chip latency gap on the same net, then runs
//! one hybrid halo-exchange phase over the global 4×4 tile lattice.
//!
//! Run: `cargo run --release --example hybrid_system`

use dnp::config::DnpConfig;
use dnp::packet::AddrFormat;
use dnp::rdma::Command;
use dnp::util::{median, percentile};
use dnp::{topology, traffic};

const CHIPS: [u32; 3] = [2, 2, 1];
const TILES: [u32; 2] = [2, 2];

fn main() {
    // 1. The hybrid render of the parametric DNP: N=4 on-chip mesh ports,
    //    M=6 off-chip torus ports behind one switch.
    let cfg = DnpConfig::hybrid();
    println!(
        "DNP config: L={} N={} M={} ({} chips x {} tiles = {} DNPs)",
        cfg.l_ports,
        cfg.n_ports,
        cfg.m_ports,
        CHIPS.iter().product::<u32>(),
        TILES.iter().product::<u32>(),
        CHIPS.iter().product::<u32>() * TILES.iter().product::<u32>(),
    );
    let fmt = AddrFormat::Hybrid { chip_dims: CHIPS, tile_dims: TILES };
    let mut net = topology::hybrid_torus_mesh(CHIPS, TILES, &cfg, 1 << 16);

    // 2. One PUT to an on-chip neighbour tile, one to the diagonally
    //    opposite chip: same API, two latency regimes.
    let near = fmt.encode(&[0, 0, 0, 1, 0]);
    let far = fmt.encode(&[1, 1, 0, 1, 1]);
    let near_node = traffic::hybrid_node_index(CHIPS, TILES, [0, 0, 0], [1, 0]);
    let far_node = traffic::hybrid_node_index(CHIPS, TILES, [1, 1, 0], [1, 1]);
    let payload: Vec<u32> = (0..64).map(|i| 0x5A17_0000 | i).collect();
    net.dnp_mut(0).mem.write_slice(0x1000, &payload);
    net.dnp_mut(near_node).register_buffer(0x4000, 256, 0).unwrap();
    net.dnp_mut(far_node).register_buffer(0x4000, 256, 0).unwrap();
    net.issue(0, Command::put(0x1000, near, 0x4000, 64).with_tag(1));
    net.issue(0, Command::put(0x1000, far, 0x4000, 64).with_tag(2));
    net.run_until_idle(1_000_000).expect("PUTs complete");
    assert_eq!(net.dnp(near_node).mem.read_slice(0x4000, 64), &payload[..]);
    assert_eq!(net.dnp(far_node).mem.read_slice(0x4000, 64), &payload[..]);
    let lat = |tag: u32| {
        let t = net.pkt_of_tag(tag).expect("trace");
        t.delivered.unwrap() - t.injected.unwrap()
    };
    println!(
        "PUT of 64 words: on-chip neighbour {} cycles, cross-chip (2 SerDes hops) {} cycles",
        lat(1),
        lat(2)
    );

    // 3. A hybrid halo-exchange phase: the global 4×4 tile lattice, every
    //    site exchanging with its 4 neighbours — on-chip in the mesh
    //    interior, over SerDes at chip edges.
    let mut net = topology::hybrid_torus_mesh(CHIPS, TILES, &cfg, 1 << 16);
    let slots: Vec<usize> = (0..net.nodes.len()).collect();
    traffic::setup_buffers(&mut net, &slots);
    let plan = traffic::hybrid_halo_exchange(CHIPS, TILES, 64);
    let msgs = plan.len();
    let mut feeder = traffic::Feeder::new(plan);
    let cycles = traffic::run_plan(&mut net, &mut feeder, 10_000_000).expect("halo drains");
    let lats: Vec<f64> = net
        .traces
        .pkts
        .values()
        .filter_map(|p| Some((p.delivered? - p.injected?) as f64))
        .collect();
    println!(
        "halo phase: {} messages x 64 words in {} cycles (packet latency median {:.0}, p95 {:.0})",
        msgs,
        cycles,
        median(&lats),
        percentile(&lats, 95.0)
    );
    assert_eq!(net.traces.delivered, msgs as u64);
    assert_eq!(net.traces.lut_misses, 0);
}
