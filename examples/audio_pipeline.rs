//! MPSoC streaming scenario — the paper's *other* motivating domain: the
//! DNP-equipped chip was "dedicated to both high performance audio/video
//! processing and theoretical physics applications" (abstract).
//!
//! An 8-stage audio-processing pipeline is mapped onto an 8-tile MTNoC
//! chip: tile k receives a frame in a SEND-landed buffer (the *eager*
//! protocol of Sec. II-A), "processes" it, and SENDs it to tile k+1. The
//! example measures per-frame pipeline latency and steady-state frame
//! throughput over the ST-Spidergon NoC, and shows the LUT/SEND buffer
//! recycling a real streaming application would do.
//!
//! Run: `cargo run --release --example audio_pipeline`

use dnp::config::DnpConfig;
use dnp::packet::AddrFormat;
use dnp::rdma::{Command, CqReader, EventKind, LUT_SENDOK};
use dnp::topology;

const FRAME_WORDS: u32 = 128; // 512-byte audio frame
const FRAMES: usize = 16;
const STAGES: usize = 8;

fn main() {
    let cfg = DnpConfig::mtnoc();
    let mut net = topology::spidergon_chip(STAGES as u32, &cfg, 1 << 16);
    let fmt = AddrFormat::Flat { n: STAGES as u32 };

    // Each stage pre-registers a ring of SEND-landing buffers (the eager
    // protocol needs a registered pool; software re-registers after use).
    const POOL: u32 = 8;
    for t in 0..STAGES {
        for b in 0..POOL {
            net.dnp_mut(t)
                .register_buffer(0x4000 + b * FRAME_WORDS, FRAME_WORDS, LUT_SENDOK)
                .unwrap();
        }
    }

    // CQ readers play the per-tile "DSP firmware".
    let mut readers: Vec<CqReader> = (0..STAGES)
        .map(|t| CqReader::new(net.dnp(t).cq.base(), cfg.cq_len))
        .collect();

    // Stage 0 emits frames; each stage forwards on receipt.
    let mut emitted = 0usize;
    let mut completed: Vec<(usize, u64)> = Vec::new(); // (frame, cycle)
    let mut started: Vec<u64> = Vec::new();
    let mut inflight_between_frames = 6; // pacing: new frame every N00 cycles

    let mut next_emit = 0u64;
    let max_cycles = 3_000_000u64;
    while completed.len() < FRAMES && net.cycle < max_cycles {
        // Source: inject a new frame into stage 0's own memory and SEND it
        // to stage 1.
        if emitted < FRAMES && net.cycle >= next_emit {
            let frame: Vec<u32> = (0..FRAME_WORDS).map(|i| (emitted as u32) << 16 | i).collect();
            net.dnp_mut(0).mem.write_slice(0x1000, &frame);
            let dst = fmt.encode(&[1]);
            net.issue(
                0,
                Command::send(0x1000, dst, FRAME_WORDS).with_tag(emitted as u32),
            );
            started.push(net.cycle);
            emitted += 1;
            next_emit = net.cycle + 600; // source frame cadence
            inflight_between_frames = inflight_between_frames.max(1);
        }

        net.step();

        // Stages 1..7: on SendLanded, forward the frame to the next stage
        // (stage 7 completes it) and re-register the consumed buffer.
        for t in 1..STAGES {
            // Split-borrow dance: poll events first, then act.
            let events: Vec<_> = {
                let d = net.dnp(t);
                let mut evs = Vec::new();
                while let Some(ev) = readers[t].poll(&d.mem, &d.cq) {
                    evs.push(ev);
                }
                evs
            };
            for ev in events {
                if ev.kind != EventKind::SendLanded {
                    continue;
                }
                // "Process" the frame (a real DSP would run a filter
                // here); the frame id rides in the first word's high half.
                let frame_id = (net.dnp(t).mem.read(ev.addr) >> 16) as usize;
                if t == STAGES - 1 {
                    completed.push((frame_id, net.cycle));
                } else {
                    let dst = fmt.encode(&[(t + 1) as u32]);
                    net.issue(
                        t,
                        Command::send(ev.addr, dst, FRAME_WORDS).with_tag(frame_id as u32),
                    );
                }
                // Recycle the landing buffer for the next frame.
                net.dnp_mut(t)
                    .register_buffer(ev.addr, FRAME_WORDS, LUT_SENDOK)
                    .expect("LUT slot");
            }
        }
    }

    assert_eq!(completed.len(), FRAMES, "pipeline wedged");
    let lat: Vec<f64> = completed
        .iter()
        .map(|&(f, end)| (end - started[f]) as f64)
        .collect();
    let first = completed.iter().map(|&(_, c)| c).min().unwrap();
    let last = completed.iter().map(|&(_, c)| c).max().unwrap();
    let thr = (FRAMES - 1) as f64 / (last - first) as f64;
    println!("audio pipeline: {STAGES} stages on an 8-tile MTNoC chip");
    println!(
        "frames: {FRAMES} x {FRAME_WORDS} words; per-frame pipeline latency median {:.0} cycles",
        dnp::util::median(&lat)
    );
    println!(
        "steady-state throughput: {:.4} frames/cycle = {:.1} kframes/s @500 MHz",
        thr,
        thr * 500e6 / 1e3
    );
    let _ = inflight_between_frames;
}
