//! Congestion-adaptive (UGAL-lite) gateway selection head-to-head
//! (ISSUE 9 acceptance).
//!
//! Two measured legs plus a static certification matrix, each printing
//! greppable `[adaptive]` rows for the CI experiments-summary artifact
//! (EXPERIMENTS.md §Adaptive documents the harvest line):
//!
//! 1. **Asymmetric hotspot** (4-chip X ring, 2x2 tiles): every sender
//!    targets destination tiles that hash onto ONE lane, the adversarial
//!    worst case for the static `DstHash` map. `Adaptive` must beat it on
//!    both the busiest-cable load and the drain time.
//! 2. **Balanced all-pairs** (2x2x2): lane-balanced traffic where the
//!    hysteresis threshold must keep `Adaptive` within ε = 5% of
//!    `DstHash` (minimal picks are stamp-free and bit-identical).
//! 3. **Certification**: `verify::check_adaptive` proves every stamped
//!    route set deadlock-free (one walk per forced lane stamp + union
//!    CDG acyclicity) across the shipped configuration matrix.
//!
//! Run: `cargo run --release --example hybrid_adaptive`

use dnp::config::DnpConfig;
use dnp::metrics::{adaptive_decision_report, gateway_load_report};
use dnp::route::GatewayMap;
use dnp::{topology, traffic, verify};

const TILES: [u32; 2] = [2, 2];

struct Leg {
    peak: u64,
    drain: u64,
    delivered: u64,
    alternate: u64,
    fraction: f64,
}

/// Run `plan` on a `chips` system under `gmap` with one wide RX window
/// per tile, and return the gateway-load peak plus adaptive stats.
fn run(chips: [u32; 3], gmap: &GatewayMap, plan: Vec<traffic::Planned>) -> Leg {
    let cfg = DnpConfig::hybrid();
    let (mut net, wiring) = topology::hybrid_torus_mesh_wired_with(chips, gmap, &cfg, 1 << 17);
    net.traces.enabled = false;
    let n = net.nodes.len();
    let window = n as u32 * traffic::RX_WINDOW;
    for i in 0..n {
        net.dnp_mut(i)
            .register_buffer(traffic::rx_addr(0), window, 0)
            .expect("LUT capacity");
    }
    let total = plan.len() as u64;
    let mut feeder = traffic::Feeder::new(plan);
    let drain = traffic::run_plan(&mut net, &mut feeder, 10_000_000).expect("plan drains");
    assert_eq!(net.traces.delivered, total, "every PUT must deliver");
    let rep = adaptive_decision_report(&net);
    Leg {
        peak: gateway_load_report(&net, &wiring).peak_channel_words(),
        drain,
        delivered: net.traces.delivered,
        alternate: rep.alternate,
        fraction: rep.alternate_fraction(),
    }
}

fn row(leg: &str, map: &str, l: &Leg) {
    println!(
        "[adaptive] leg={leg} map={map} peak_words={} drain_cycles={} delivered={} \
         alternate_picks={} alternate_fraction={:.3}",
        l.peak, l.drain, l.delivered, l.alternate, l.fraction,
    );
}

fn main() {
    let cfg = DnpConfig::hybrid();

    // Leg 1: the hash-adversarial funnel. The skew is computed against
    // the static hash, which both maps share — identical plans.
    let chips = [4u32, 1, 1];
    let hash_map = GatewayMap::dst_hash(TILES, 2);
    let ada_map = GatewayMap::adaptive(TILES, 2);
    let funnel = |m: &GatewayMap| traffic::hybrid_asymmetric_hotspot(chips, m, [0, 0, 0], 4, 32);
    let hash = run(chips, &hash_map, funnel(&hash_map));
    let ada = run(chips, &ada_map, funnel(&ada_map));
    row("asym-hotspot-4x1x1", "dsthash", &hash);
    row("asym-hotspot-4x1x1", "adaptive", &ada);
    assert_eq!(hash.delivered, ada.delivered, "same workload, same deliveries");
    assert!(ada.alternate > 0, "the funnel must trigger alternate-lane picks");
    assert!(
        ada.peak < hash.peak && ada.drain < hash.drain,
        "Adaptive (peak {}, drain {}) must beat DstHash (peak {}, drain {})",
        ada.peak,
        ada.drain,
        hash.peak,
        hash.drain,
    );

    // Leg 2: lane-balanced all-pairs — hysteresis must hold Adaptive
    // within 5% of the static hash.
    let chips = [2u32, 2, 2];
    let hash = run(chips, &hash_map, traffic::hybrid_all_pairs(chips, TILES, 16));
    let ada = run(chips, &ada_map, traffic::hybrid_all_pairs(chips, TILES, 16));
    row("all-pairs-2x2x2", "dsthash", &hash);
    row("all-pairs-2x2x2", "adaptive", &ada);
    assert_eq!(hash.delivered, ada.delivered);
    assert!(
        ada.peak * 20 <= hash.peak * 21 && ada.drain * 20 <= hash.drain * 21,
        "Adaptive (peak {}, drain {}) must stay within 5% of DstHash (peak {}, drain {})",
        ada.peak,
        ada.drain,
        hash.peak,
        hash.drain,
    );

    // Leg 3: static certification of every stamped route set.
    let mut all_ok = true;
    for topo in [[2, 2, 1], [3, 3, 1], [4, 4, 1], [3, 3, 3]] {
        for lanes in [2usize, 4] {
            let rep = verify::check_adaptive(topo, &GatewayMap::adaptive(TILES, lanes), &cfg);
            let certified = rep.is_certified();
            println!(
                "[adaptive] leg=certify topo={}x{}x{} lanes={lanes} stamps={} \
                 max_chans={} max_edges={} certified={}",
                topo[0],
                topo[1],
                topo[2],
                rep.stamps.len(),
                rep.stamps.iter().map(|s| s.chans.len()).max().unwrap_or(0),
                rep.stamps.iter().map(|s| s.edges.len()).max().unwrap_or(0),
                if certified { "yes" } else { "no" },
            );
            if !certified {
                if let Some(c) = rep.union_cycle {
                    println!("--- union CDG cycle through {c:?}");
                }
                for (s, r) in rep.stamps.iter().enumerate() {
                    if !r.is_certified() {
                        println!("--- stamp {s} report ---\n{r}");
                    }
                }
            }
            all_ok &= certified;
        }
    }
    assert!(all_ok, "some adaptive configuration failed static verification");
    println!("[adaptive] all legs passed");
}
