//! Quickstart: build a two-tile DNP-Net, register an RDMA buffer, PUT a
//! block of data across the off-chip SerDes link, and read the paper's
//! latency breakdown off the traces.
//!
//! Run: `cargo run --release --example quickstart`

use dnp::config::DnpConfig;
use dnp::metrics;
use dnp::packet::AddrFormat;
use dnp::rdma::{Command, CqReader, EventKind};
use dnp::topology;

fn main() {
    // 1. A parametric DNP in its SHAPES RDT render: L=2, N=1, M=6.
    let cfg = DnpConfig::shapes_rdt();
    println!(
        "DNP config: L={} N={} M={} (up to {} simultaneous transactions)",
        cfg.l_ports,
        cfg.n_ports,
        cfg.m_ports,
        cfg.max_transactions()
    );

    // 2. Two tiles joined by one bidirectional off-chip SerDes link.
    let mut net = topology::two_tiles_offchip(&cfg, 1 << 16);
    let fmt = AddrFormat::Torus3D { dims: [2, 1, 1] };
    let dst = fmt.encode(&[1, 0, 0]);

    // 3. Software on tile 1 registers a destination buffer in the LUT.
    net.dnp_mut(1).register_buffer(0x4000, 256, 0).unwrap();

    // 4. Software on tile 0 seeds data and pushes a PUT into the CMD FIFO.
    let payload: Vec<u32> = (0..64).map(|i| 0xAB00_0000 | i).collect();
    net.dnp_mut(0).mem.write_slice(0x1000, &payload);
    net.issue(0, Command::put(0x1000, dst, 0x4000, 64).with_tag(1));

    // 5. Run the cycle-accurate simulation until everything drains.
    let cycles = net.run_until_idle(100_000).expect("PUT completes");
    assert_eq!(net.dnp(1).mem.read_slice(0x4000, 64), &payload[..]);
    println!("PUT of 64 words completed in {cycles} cycles");

    // 6. The latency breakdown of the paper's Fig. 9/10.
    let b = metrics::breakdown(&net, 0, 1).expect("trace");
    println!(
        "breakdown: L1={} L2={} L3={} L4={} -> total {} cycles ({:.0} ns @500 MHz)",
        b.l1,
        b.l2,
        b.l3,
        b.l4,
        b.total(),
        b.total_ns(cfg.freq_mhz)
    );

    // 7. Completion events, exactly as tile software would poll them.
    let d1 = net.dnp(1);
    let mut rd = CqReader::new(d1.cq.base(), cfg.cq_len);
    while let Some(ev) = rd.poll(&d1.mem, &d1.cq) {
        assert_eq!(ev.kind, EventKind::PacketWritten);
        println!(
            "tile1 CQ: {:?} from {} at 0x{:x} len {}",
            ev.kind, ev.peer, ev.addr, ev.len_or_tag
        );
    }
}
