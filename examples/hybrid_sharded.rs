//! Sharded-vs-sequential tour of the hybrid system: the same 2×2×2-chip
//! torus of 2×2 tile meshes (32 DNPs) runs a halo-exchange phase and a
//! uniform-random plan three ways — under the sequential event scheduler
//! (`traffic::run_plan`) and sharded per chip on worker threads
//! (`traffic::run_plan_sharded`) with every parallel runner (windowed
//! barrier, per-link conservative clocks, and the work-stealing shard
//! pool) — and asserts all of them agree bit-exactly on drain cycles
//! and every delivery counter.
//!
//! Run: `cargo run --release --example hybrid_sharded [workers]`
//! (default 2 workers; CI runs this as the sharded smoke).

use dnp::config::DnpConfig;
use dnp::metrics::{net_totals, scheduler_totals, sharded_totals};
use dnp::sim::{ParallelMode, ShardedNet};
use dnp::{topology, traffic};

const CHIPS: [u32; 3] = [2, 2, 2];
const TILES: [u32; 2] = [2, 2];
const MEM: usize = 1 << 16;

fn main() {
    let workers: usize = std::env::args()
        .nth(1)
        .map(|a| a.parse().expect("workers must be a number"))
        .unwrap_or(2);
    let cfg = DnpConfig::hybrid();
    let n = (CHIPS.iter().product::<u32>() * TILES.iter().product::<u32>()) as usize;
    println!(
        "hybrid {}x{}x{} chips of {}x{} tiles = {} DNPs, {} shards on {} workers",
        CHIPS[0],
        CHIPS[1],
        CHIPS[2],
        TILES[0],
        TILES[1],
        n,
        CHIPS.iter().product::<u32>(),
        workers,
    );

    for (name, plan) in [
        ("halo", traffic::hybrid_halo_exchange(CHIPS, TILES, 48)),
        (
            "uniform",
            traffic::hybrid_uniform_random(CHIPS, TILES, 8, 32, 8, 0x5AAD_0002),
        ),
    ] {
        // Sequential event scheduler (wired build: the HybridWiring's
        // partition maps every SerDes wire onto its sharded twin below).
        let (mut net, wiring) = topology::hybrid_torus_mesh_wired(CHIPS, TILES, &cfg, MEM);
        let slots: Vec<usize> = (0..n).collect();
        traffic::setup_buffers(&mut net, &slots);
        let mut feeder = traffic::Feeder::new(plan.clone());
        let seq = traffic::run_plan(&mut net, &mut feeder, 10_000_000).expect("sequential drains");
        let seq_totals = net_totals(&net);

        // Per-chip shards on worker threads, under every parallel runner.
        for mode in
            [ParallelMode::Barrier, ParallelMode::LinkClock, ParallelMode::WorkSteal]
        {
            let mut snet =
                ShardedNet::hybrid(CHIPS, TILES, &cfg, MEM, workers).expect("uniform links");
            snet.set_parallel_mode(mode);
            traffic::setup_buffers_sharded(&mut snet);
            let shd = traffic::run_plan_sharded(&mut snet, plan.clone(), 10_000_000)
                .expect("sharded drains");
            let shd_totals = sharded_totals(&snet);

            println!(
                "{name} [{mode:?}]: {} messages, sequential {} cycles, sharded {} cycles \
                 (horizon {} cycles)",
                plan.len(),
                seq,
                shd,
                snet.horizon(),
            );
            assert_eq!(seq, shd, "{name} ({mode:?}): drain cycles diverged");
            assert_eq!(seq_totals, shd_totals, "{name} ({mode:?}): counters diverged");
            assert_eq!(shd_totals.delivered, plan.len() as u64);
            assert_eq!(shd_totals.lut_misses, 0);
            // Per-wire agreement: every directed SerDes wire carried exactly
            // the words the sequential build's twin channel carried.
            for (i, l) in wiring.partition().links.iter().enumerate() {
                let seq_words = net.chans.get(l.chan).words_sent;
                assert_eq!(
                    seq_words,
                    snet.link_words_sent(i),
                    "wire {i} (chip {} dim {} {}) words diverged",
                    l.from_chip,
                    l.dim,
                    if l.plus { "+" } else { "-" },
                );
            }
            let sched = scheduler_totals(&snet);
            println!(
                "EXPERIMENTS: shard-smoke {name} mode={mode:?} cycles={seq} delivered={} \
                 rounds={} null-windows={}",
                shd_totals.delivered, sched.rounds, sched.null_windows,
            );
        }
    }
    println!("sharded == sequential on every counter and every wire: OK");
}
