//! Fault recovery on the hybrid multi-chip system (paper Sec. V roadmap;
//! cf. the APEnet+ fault-management follow-up, arXiv:1307.1270).
//!
//! Three acts on a 2×2 chip torus of 2×2 tile meshes:
//!
//! 1. healthy baseline — staggered all-pairs PUT traffic;
//! 2. hard fault — every off-chip cable of one gateway tile dies, the
//!    two-level tables are recomputed over the survivor graph and
//!    installed through the programmable RTR, the same traffic re-runs:
//!    everything still delivers, the dead wires stay silent, and the
//!    detour cost is visible in the drain time;
//! 3. soft fault — bit errors on the SerDes corrupt payloads in flight;
//!    the destination CQs flag them (`CorruptPayload`) and the
//!    traffic-layer retry loop re-issues until every window is clean.
//!
//! Run: `cargo run --release --example hybrid_fault_recovery`

use dnp::config::DnpConfig;
use dnp::fault::{self, HierLinkFault};
use dnp::{topology, traffic};

const CHIPS: [u32; 3] = [2, 2, 1];
const TILES: [u32; 2] = [2, 2];
const N: usize = 16;
const LEN: u32 = 8;

fn main() {
    let cfg = DnpConfig::hybrid();
    println!(
        "hybrid system: {}x{}x{} chips of {}x{} tiles, L={} N={} M={}",
        CHIPS[0], CHIPS[1], CHIPS[2], TILES[0], TILES[1], cfg.l_ports, cfg.n_ports, cfg.m_ports
    );

    // --- Act 1: healthy baseline.
    let mut net = topology::hybrid_torus_mesh(CHIPS, TILES, &cfg, 1 << 16);
    let slots: Vec<usize> = (0..N).collect();
    traffic::setup_buffers(&mut net, &slots);
    let mut feeder = traffic::Feeder::new(traffic::hybrid_all_pairs(CHIPS, TILES, LEN));
    let healthy_cycles = traffic::run_plan(&mut net, &mut feeder, 5_000_000).expect("drains");
    println!(
        "healthy:   all-pairs ({} PUTs x {LEN} words) drained in {healthy_cycles} cycles",
        N * (N - 1)
    );

    // --- Act 2: the dim-0 gateway of chip (0,0,0) loses every off-chip
    // cable; its dimension re-homes onto the dim-1 ring.
    let (mut net, wiring) = topology::hybrid_torus_mesh_wired(CHIPS, TILES, &cfg, 1 << 16);
    traffic::setup_buffers(&mut net, &slots);
    let faults = [
        HierLinkFault::Serdes { chip: [0, 0, 0], dim: 0, plus: true },
        HierLinkFault::Serdes { chip: [0, 0, 0], dim: 0, plus: false },
    ];
    let dead = fault::inject_hybrid(&mut net, &wiring, &faults, &cfg)
        .expect("survivor graph stays connected");
    let mut feeder = traffic::Feeder::new(traffic::hybrid_all_pairs(CHIPS, TILES, LEN));
    let faulted_cycles = traffic::run_plan(&mut net, &mut feeder, 5_000_000)
        .expect("recovered tables must still drain");
    let dead_words: u64 = dead.iter().map(|&c| net.chans.get(c).words_sent).sum();
    println!(
        "gateway isolated: same traffic drained in {faulted_cycles} cycles \
         (+{} vs healthy), delivered {}, dead wires carried {dead_words} flits",
        faulted_cycles as i64 - healthy_cycles as i64,
        net.traces.delivered,
    );
    assert_eq!(net.traces.delivered, (N * (N - 1)) as u64);
    assert_eq!(dead_words, 0, "a dead wire carried traffic");

    // --- Act 3: SerDes bit errors + CQ-driven end-to-end retry.
    let mut cfg_ber = cfg.clone();
    cfg_ber.serdes.ber_per_word = 1e-2;
    let mut net = topology::hybrid_torus_mesh(CHIPS, TILES, &cfg_ber, 1 << 16);
    traffic::setup_buffers(&mut net, &slots);
    let plan = traffic::hybrid_uniform_random(CHIPS, TILES, 6, 32, 10, 0xFA17_0001);
    let msgs = plan.len();
    let report =
        traffic::retrying_plan(&mut net, plan, 5_000_000, 40).expect("retry loop converges");
    println!(
        "BER 1e-2: {msgs} cross-chip PUTs, {} corrupted in flight, {} retries over {} rounds, \
         clean after {} cycles",
        net.traces.corrupt_packets, report.retries, report.rounds, report.elapsed
    );
    assert_eq!(report.retries, net.traces.corrupt_packets);
}
