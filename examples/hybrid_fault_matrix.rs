//! The 4x4x4 fault matrix at a glance (ISSUE 6 acceptance; paper Sec. V
//! roadmap, cf. the APEnet+ fault-management follow-up, arXiv:1307.1270).
//!
//! Runs chip-granular all-pairs traffic on a 4×4×4 chip torus of 2×2
//! tile meshes — k=4 rings, routable only since the per-channel dateline
//! class rework — under each hard-fault scenario of the recovery matrix,
//! plus a BER + retry leg, and prints one `[fault-matrix]` row per
//! scenario for the CI experiments-summary artifact (EXPERIMENTS.md
//! §Fault documents the harvest line).
//!
//! Run: `cargo run --release --example hybrid_fault_matrix`

use dnp::config::DnpConfig;
use dnp::fault::{self, HierLinkFault};
use dnp::{topology, traffic};

const CHIPS: [u32; 3] = [4, 4, 4];
const TILES: [u32; 2] = [2, 2];
const NCHIPS: usize = 64;
const MEM: usize = 1 << 17;
const LEN: u32 = 8;
const BUDGET: u64 = 20_000_000;

fn run_hard(faults: &[HierLinkFault], label: &str, healthy: Option<u64>) -> u64 {
    let cfg = DnpConfig::hybrid();
    let (mut net, wiring) = topology::hybrid_torus_mesh_wired(CHIPS, TILES, &cfg, MEM);
    traffic::setup_chip_buffers(&mut net, NCHIPS);
    let dead = fault::inject_hybrid(&mut net, &wiring, faults, &cfg)
        .expect("matrix scenarios are recoverable at k=4");
    let plan = traffic::hybrid_chip_all_pairs(CHIPS, TILES, LEN);
    let total = plan.len() as u64;
    let mut feeder = traffic::Feeder::new(plan);
    let cycles = traffic::run_plan(&mut net, &mut feeder, BUDGET)
        .expect("recovered tables must drain chip all-pairs");
    let dead_words: u64 = dead.iter().map(|&c| net.chans.get(c).words_sent).sum();
    assert_eq!(net.traces.delivered, total);
    assert_eq!(dead_words, 0, "a dead wire carried traffic");
    let delta = healthy.map(|h| cycles as i64 - h as i64);
    println!(
        "[fault-matrix] scenario={label} chips=4x4x4 puts={total} cycles={cycles} \
         delta_vs_healthy={} delivered={} dead_wire_words={dead_words}",
        delta.map_or_else(|| "n/a".into(), |d| format!("{d:+}")),
        net.traces.delivered,
    );
    cycles
}

fn main() {
    let cfg = DnpConfig::hybrid();
    println!(
        "hybrid system: {}x{}x{} chips of {}x{} tiles, L={} N={} M={}",
        CHIPS[0], CHIPS[1], CHIPS[2], TILES[0], TILES[1], cfg.l_ports, cfg.n_ports, cfg.m_ports
    );

    let healthy = run_hard(&[], "healthy", None);

    run_hard(
        &[HierLinkFault::Serdes { chip: [1, 2, 3], dim: 2, plus: true }],
        "dead-cable",
        Some(healthy),
    );
    run_hard(
        &[
            HierLinkFault::Serdes { chip: [0, 0, 0], dim: 0, plus: true },
            HierLinkFault::Serdes { chip: [0, 0, 0], dim: 0, plus: false },
        ],
        "isolated-gateway",
        Some(healthy),
    );
    run_hard(
        &[HierLinkFault::Mesh { chip: [2, 1, 0], tile: [0, 0], dim: 0, plus: true }],
        "dead-mesh-link",
        Some(healthy),
    );
    run_hard(
        &[
            HierLinkFault::Serdes { chip: [3, 0, 1], dim: 1, plus: true },
            HierLinkFault::Mesh { chip: [1, 3, 2], tile: [1, 0], dim: 1, plus: true },
        ],
        "combined",
        Some(healthy),
    );

    // BER + CQ-driven end-to-end retry on the k=4 rings.
    let mut cfg_ber = cfg.clone();
    cfg_ber.serdes.ber_per_word = 1e-3;
    let mut net = topology::hybrid_torus_mesh(CHIPS, TILES, &cfg_ber, MEM);
    traffic::setup_chip_buffers(&mut net, NCHIPS);
    let plan = traffic::hybrid_chip_all_pairs(CHIPS, TILES, LEN);
    let msgs = plan.len();
    let report = traffic::retrying_plan(&mut net, plan, BUDGET, 40)
        .expect("retry loop converges at 4x4x4");
    assert_eq!(report.retries, net.traces.corrupt_packets);
    println!(
        "[fault-matrix] scenario=ber-retry chips=4x4x4 puts={msgs} cycles={} \
         corrupted={} retries={} rounds={}",
        report.elapsed, net.traces.corrupt_packets, report.retries, report.rounds
    );
}
