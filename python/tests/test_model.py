"""L2 correctness: the dslash model (Pallas-backed) against the naive
complex oracle, plus shape/physics sanity used by the AOT artifacts."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _fields(seed, l):
    rng = np.random.default_rng(seed)
    lp = l + 2
    psi_re = rng.standard_normal((lp, lp, lp, 3)).astype(np.float32)
    psi_im = rng.standard_normal((lp, lp, lp, 3)).astype(np.float32)
    u_re = rng.standard_normal((3, lp, lp, lp, 3, 3)).astype(np.float32)
    u_im = rng.standard_normal((3, lp, lp, lp, 3, 3)).astype(np.float32)
    return psi_re, psi_im, u_re, u_im


@pytest.mark.parametrize("l", [2, 4])
def test_dslash_matches_ref(l):
    psi_re, psi_im, u_re, u_im = _fields(5, l)
    got_re, got_im, got_n = model.dslash(psi_re, psi_im, u_re, u_im)
    want_re, want_im, want_n = ref.dslash_ref(psi_re, psi_im, u_re, u_im)
    np.testing.assert_allclose(got_re, want_re, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(got_im, want_im, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(got_n, want_n, rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_dslash_hypothesis_l4(seed):
    psi_re, psi_im, u_re, u_im = _fields(seed, 4)
    got_re, got_im, _ = model.dslash(psi_re, psi_im, u_re, u_im)
    want_re, want_im, _ = ref.dslash_ref(psi_re, psi_im, u_re, u_im)
    np.testing.assert_allclose(got_re, want_re, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(got_im, want_im, rtol=5e-4, atol=5e-4)


def test_dslash_output_shapes():
    psi_re, psi_im, u_re, u_im = _fields(1, 4)
    out_re, out_im, n = model.dslash(psi_re, psi_im, u_re, u_im)
    assert out_re.shape == (4, 4, 4, 3)
    assert out_im.shape == (4, 4, 4, 3)
    assert n.shape == ()
    assert float(n) > 0


def test_dslash_is_linear_in_psi():
    psi_re, psi_im, u_re, u_im = _fields(2, 4)
    a_re, a_im, _ = model.dslash(psi_re, psi_im, u_re, u_im)
    b_re, b_im, _ = model.dslash(2 * psi_re, 2 * psi_im, u_re, u_im)
    np.testing.assert_allclose(2 * np.asarray(a_re), b_re, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(2 * np.asarray(a_im), b_im, rtol=1e-4, atol=1e-4)


def test_dslash_zero_field_gives_zero():
    _, _, u_re, u_im = _fields(3, 4)
    z = np.zeros((6, 6, 6, 3), np.float32)
    out_re, out_im, n = model.dslash(z, z, u_re, u_im)
    assert float(n) == 0.0
    assert not np.any(np.asarray(out_re))
    assert not np.any(np.asarray(out_im))


def test_axpy_and_norm2():
    rng = np.random.default_rng(0)
    x_re = rng.standard_normal(16).astype(np.float32)
    x_im = rng.standard_normal(16).astype(np.float32)
    y_re = rng.standard_normal(16).astype(np.float32)
    y_im = rng.standard_normal(16).astype(np.float32)
    o_re, o_im = model.axpy(np.float32(2.0), x_re, x_im, y_re, y_im)
    np.testing.assert_allclose(o_re, y_re + 2 * x_re, rtol=1e-6)
    np.testing.assert_allclose(o_im, y_im + 2 * x_im, rtol=1e-6)
    n = model.norm2(x_re, x_im)
    np.testing.assert_allclose(n, np.sum(x_re**2 + x_im**2), rtol=1e-5)
