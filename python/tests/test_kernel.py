"""L1 correctness: the Pallas SU(3) kernel against the pure-jnp oracle.

The hypothesis sweeps cover site counts (block-aligned and ragged) and
value scales; assert_allclose against ref.py is THE correctness signal
for everything the rust runtime later executes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, su3


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


def _case(seed, sites):
    rng = np.random.default_rng(seed)
    return (
        _rand(rng, sites, 3, 3),
        _rand(rng, sites, 3, 3),
        _rand(rng, sites, 3),
        _rand(rng, sites, 3),
    )


@pytest.mark.parametrize("sites", [1, 3, 64, 128, 256, 384])
def test_su3_apply_matches_ref(sites):
    u_re, u_im, v_re, v_im = _case(42, sites)
    got_re, got_im = su3.su3_apply(u_re, u_im, v_re, v_im)
    want_re, want_im = ref.su3_apply_ref(u_re, u_im, v_re, v_im)
    np.testing.assert_allclose(got_re, want_re, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_im, want_im, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sites", [1, 64, 200])
def test_su3_dagger_matches_ref(sites):
    u_re, u_im, v_re, v_im = _case(7, sites)
    got_re, got_im = su3.su3_apply_dagger(u_re, u_im, v_re, v_im)
    want_re, want_im = ref.su3_apply_dagger_ref(u_re, u_im, v_re, v_im)
    np.testing.assert_allclose(got_re, want_re, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_im, want_im, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    sites=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.floats(min_value=1e-3, max_value=1e3),
    block=st.sampled_from([16, 64, 128]),
)
def test_su3_apply_hypothesis(sites, seed, scale, block):
    rng = np.random.default_rng(seed)
    u_re = _rand(rng, sites, 3, 3) * scale
    u_im = _rand(rng, sites, 3, 3) * scale
    v_re = _rand(rng, sites, 3)
    v_im = _rand(rng, sites, 3)
    got_re, got_im = su3.su3_apply(u_re, u_im, v_re, v_im, block=block)
    want_re, want_im = ref.su3_apply_ref(u_re, u_im, v_re, v_im)
    np.testing.assert_allclose(got_re, want_re, rtol=1e-4, atol=1e-4 * scale)
    np.testing.assert_allclose(got_im, want_im, rtol=1e-4, atol=1e-4 * scale)


def test_unitary_links_preserve_norm():
    # SU(3) links are unitary: |U v| == |v|. Build U via QR.
    rng = np.random.default_rng(3)
    sites = 64
    a = rng.standard_normal((sites, 3, 3)) + 1j * rng.standard_normal((sites, 3, 3))
    q, _ = np.linalg.qr(a)
    v = rng.standard_normal((sites, 3)) + 1j * rng.standard_normal((sites, 3))
    got_re, got_im = su3.su3_apply(
        np.real(q).astype(np.float32),
        np.imag(q).astype(np.float32),
        np.real(v).astype(np.float32),
        np.imag(v).astype(np.float32),
    )
    norm_in = np.sum(np.abs(v) ** 2)
    norm_out = np.sum(got_re.astype(np.float64) ** 2 + got_im.astype(np.float64) ** 2)
    np.testing.assert_allclose(norm_out, norm_in, rtol=1e-4)


def test_dagger_inverts_apply_for_unitary():
    rng = np.random.default_rng(11)
    sites = 32
    a = rng.standard_normal((sites, 3, 3)) + 1j * rng.standard_normal((sites, 3, 3))
    q, _ = np.linalg.qr(a)
    u_re = np.real(q).astype(np.float32)
    u_im = np.imag(q).astype(np.float32)
    v_re = _rand(rng, sites, 3)
    v_im = _rand(rng, sites, 3)
    w_re, w_im = su3.su3_apply(u_re, u_im, v_re, v_im)
    b_re, b_im = su3.su3_apply_dagger(u_re, u_im, np.asarray(w_re), np.asarray(w_im))
    np.testing.assert_allclose(b_re, v_re, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(b_im, v_im, rtol=1e-4, atol=1e-5)
