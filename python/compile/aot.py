"""AOT bridge: lower the L2 model to HLO text for the rust runtime.

HLO *text* is the interchange format (NOT `HloModuleProto.serialize()`):
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts

Artifacts (all f32, return_tuple=True):
  dslash_<L>.hlo.txt — dslash(psi_pad re/im, u re/im) -> (out re/im, norm)
  axpy_<n>.hlo.txt   — axpy(a, x re/im, y re/im)      -> (out re/im)
  norm2_<n>.hlo.txt  — norm2(x re/im)                 -> (norm,)
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Local lattice sizes to export; 4 matches the 2x2x2 SHAPES benchmark
# tile in the rust examples (global 8^3 over 8 tiles).
LATTICE_SIZES = (4, 6)
VEC_SIZES = (4 * 4 * 4 * 3,)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_dslash(l: int) -> str:
    lp = l + 2
    f = jax.ShapeDtypeStruct((lp, lp, lp, 3), jnp.float32)
    u = jax.ShapeDtypeStruct((3, lp, lp, lp, 3, 3), jnp.float32)

    def fn(psi_re, psi_im, u_re, u_im):
        return model.dslash(psi_re, psi_im, u_re, u_im)

    return to_hlo_text(jax.jit(fn).lower(f, f, u, u))


def lower_axpy(n: int) -> str:
    s = jax.ShapeDtypeStruct((), jnp.float32)
    v = jax.ShapeDtypeStruct((n,), jnp.float32)

    def fn(a, x_re, x_im, y_re, y_im):
        return model.axpy(a, x_re, x_im, y_re, y_im)

    return to_hlo_text(jax.jit(fn).lower(s, v, v, v, v))


def lower_norm2(n: int) -> str:
    v = jax.ShapeDtypeStruct((n,), jnp.float32)

    def fn(x_re, x_im):
        return (model.norm2(x_re, x_im),)

    return to_hlo_text(jax.jit(fn).lower(v, v))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    jobs = []
    for l in LATTICE_SIZES:
        jobs.append((f"dslash_{l}", lambda l=l: lower_dslash(l)))
    for n in VEC_SIZES:
        jobs.append((f"axpy_{n}", lambda n=n: lower_axpy(n)))
        jobs.append((f"norm2_{n}", lambda n=n: lower_norm2(n)))

    for name, fn in jobs:
        path = os.path.join(args.out, f"{name}.hlo.txt")
        text = fn()
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {name}: {len(text)} chars -> {path}")


if __name__ == "__main__":
    main()
