"""L2 JAX model: the LQCD benchmark kernel of the paper's Sec. IV.

A 3D hop-term Dslash over a halo-padded local lattice, decomposed per
tile exactly as the SHAPES 2x2x2 benchmark decomposes the global lattice:
the rust driver owns the global field, exchanges halo faces through the
simulated DNP-Net (RDMA PUT), assembles the padded local array and calls
this model through PJRT. The SU(3) x vector hot-spot runs in the L1
Pallas kernel (`kernels.su3`).

Also exported: `axpy` (the CG-style linear-algebra step) and `norm2`.
"""

import jax.numpy as jnp

from compile.kernels import su3


def dslash(psi_pad_re, psi_pad_im, u_re, u_im):
    """Hop-term Dslash on a halo-padded local lattice.

    Args:
      psi_pad_re/im: (L+2, L+2, L+2, 3) float32.
      u_re/im: (3, L+2, L+2, L+2, 3, 3) float32, halo-padded.

    Returns:
      (out_re, out_im, norm): (L,L,L,3), (L,L,L,3), () — norm = sum|out|^2.
    """
    lp = psi_pad_re.shape[0]
    l = lp - 2
    interior = (slice(1, 1 + l),) * 3

    def flat(a, tail):
        return a.reshape((l * l * l,) + tail)

    out_re = jnp.zeros((l * l * l, 3), jnp.float32)
    out_im = jnp.zeros((l * l * l, 3), jnp.float32)
    for d in range(3):
        plus = [slice(1, 1 + l)] * 3
        minus = [slice(1, 1 + l)] * 3
        plus[d] = slice(2, 2 + l)
        minus[d] = slice(0, l)
        psi_p_re = flat(psi_pad_re[tuple(plus)], (3,))
        psi_p_im = flat(psi_pad_im[tuple(plus)], (3,))
        psi_m_re = flat(psi_pad_re[tuple(minus)], (3,))
        psi_m_im = flat(psi_pad_im[tuple(minus)], (3,))
        uh_re = flat(u_re[d][interior], (3, 3))
        uh_im = flat(u_im[d][interior], (3, 3))
        ub_re = flat(u_re[d][tuple(minus)], (3, 3))
        ub_im = flat(u_im[d][tuple(minus)], (3, 3))
        # Forward hop: U_d(x) psi(x+e_d) — the Pallas hot-spot.
        f_re, f_im = su3.su3_apply(uh_re, uh_im, psi_p_re, psi_p_im)
        # Backward hop: U_d(x-e_d)^dag psi(x-e_d).
        b_re, b_im = su3.su3_apply_dagger(ub_re, ub_im, psi_m_re, psi_m_im)
        out_re = out_re + f_re + b_re
        out_im = out_im + f_im + b_im
    norm = jnp.sum(out_re * out_re + out_im * out_im)
    return (
        out_re.reshape(l, l, l, 3),
        out_im.reshape(l, l, l, 3),
        norm,
    )


def axpy(a, x_re, x_im, y_re, y_im):
    """y + a*x over color fields (CG building block). `a` is a scalar."""
    return y_re + a * x_re, y_im + a * x_im


def norm2(x_re, x_im):
    """Global squared norm of a color field."""
    return jnp.sum(x_re * x_re + x_im * x_im)
