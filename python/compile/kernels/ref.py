"""Pure-jnp oracle for the Pallas kernels — the correctness reference.

Everything here is deliberately written in the most obvious way (complex
dtype, plain einsum) so the pytest comparison against the blocked Pallas
path is a genuine independent check.
"""

import jax.numpy as jnp


def su3_apply_ref(u_re, u_im, v_re, v_im):
    """out = U @ v over complex 3-vectors, the naive complex way."""
    u = u_re.astype(jnp.complex64) + 1j * u_im.astype(jnp.complex64)
    v = v_re.astype(jnp.complex64) + 1j * v_im.astype(jnp.complex64)
    out = jnp.einsum("sij,sj->si", u, v)
    return jnp.real(out).astype(jnp.float32), jnp.imag(out).astype(jnp.float32)


def su3_apply_dagger_ref(u_re, u_im, v_re, v_im):
    u = u_re.astype(jnp.complex64) + 1j * u_im.astype(jnp.complex64)
    v = v_re.astype(jnp.complex64) + 1j * v_im.astype(jnp.complex64)
    out = jnp.einsum("sji,sj->si", jnp.conj(u), v)
    return jnp.real(out).astype(jnp.float32), jnp.imag(out).astype(jnp.float32)


def dslash_ref(psi_pad_re, psi_pad_im, u_re, u_im):
    """Naive 3D hop-term Dslash on a halo-padded local lattice.

    out(x) = sum_d [ U_d(x) psi(x+e_d) + U_d(x-e_d)^dag psi(x-e_d) ]

    Args:
      psi_pad_re/im: (L+2, L+2, L+2, 3) — local field with halo faces.
      u_re/im: (3, L+2, L+2, L+2, 3, 3) — links, halo-padded the same way
        (only interior and faces are read).

    Returns:
      out_re, out_im: (L, L, L, 3) and norm: () = sum |out|^2.
    """
    lp = psi_pad_re.shape[0]
    l = lp - 2
    psi = psi_pad_re.astype(jnp.complex64) + 1j * psi_pad_im.astype(jnp.complex64)
    u = u_re.astype(jnp.complex64) + 1j * u_im.astype(jnp.complex64)
    interior = (slice(1, 1 + l),) * 3
    out = jnp.zeros((l, l, l, 3), jnp.complex64)
    for d in range(3):
        plus = [slice(1, 1 + l)] * 3
        minus = [slice(1, 1 + l)] * 3
        plus[d] = slice(2, 2 + l)
        minus[d] = slice(0, l)
        psi_p = psi[tuple(plus)]
        psi_m = psi[tuple(minus)]
        u_here = u[d][interior]
        u_back = u[d][tuple(minus)]
        out = out + jnp.einsum("xyzij,xyzj->xyzi", u_here, psi_p)
        out = out + jnp.einsum("xyzji,xyzj->xyzi", jnp.conj(u_back), psi_m)
    norm = jnp.sum(jnp.abs(out) ** 2).astype(jnp.float32)
    return (
        jnp.real(out).astype(jnp.float32),
        jnp.imag(out).astype(jnp.float32),
        norm,
    )
