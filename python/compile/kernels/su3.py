"""L1 Pallas kernel: blocked SU(3) x color-vector product.

This is the compute hot-spot of the paper's LQCD benchmark kernel
(Sec. IV: "the DNP was employed in benchmarking the SHAPES architecture on
a kernel code for Lattice Quantum Chromo Dynamics"): per lattice site, a
3x3 complex (SU(3) gauge link) matrix multiplies a 3-component complex
color vector. The Dslash hop term applies it for every direction.

Hardware adaptation (see DESIGN.md #Hardware-Adaptation): the paper's
substrate is the mAgicV VLIW FPU; on TPU the natural mapping is the MXU
via a real 2x2 embedding of complex arithmetic with sites blocked along
the batch dimension. The BlockSpec below tiles the site dimension so each
grid step streams one block of vectors HBM->VMEM while the block's links
ride along; `interpret=True` is mandatory on CPU PJRT (real-TPU lowering
emits Mosaic custom-calls the CPU plugin cannot run).

Complex data travels as separate real/imag float32 arrays because the
rust PJRT boundary is f32-typed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sites per grid step. 128 keeps the VMEM working set tiny
# (128*(9+3+3)*2*4B = 15 KiB) while filling MXU batch lanes.
DEFAULT_BLOCK = 128


def _su3_kernel(u_re_ref, u_im_ref, v_re_ref, v_im_ref, o_re_ref, o_im_ref):
    """One block: out = U @ v over complex 3-vectors, real arithmetic.

    (a + ib)(c + id) = (ac - bd) + i(ad + bc), batched over sites with
    einsum — which XLA/Mosaic lowers to MXU-shaped batched matmuls.
    """
    u_re = u_re_ref[...]
    u_im = u_im_ref[...]
    v_re = v_re_ref[...]
    v_im = v_im_ref[...]
    o_re_ref[...] = jnp.einsum("sij,sj->si", u_re, v_re) - jnp.einsum(
        "sij,sj->si", u_im, v_im
    )
    o_im_ref[...] = jnp.einsum("sij,sj->si", u_re, v_im) + jnp.einsum(
        "sij,sj->si", u_im, v_re
    )


@functools.partial(jax.jit, static_argnames=("block",))
def su3_apply(u_re, u_im, v_re, v_im, block=DEFAULT_BLOCK):
    """Apply per-site SU(3) links to color vectors.

    Args:
      u_re, u_im: (S, 3, 3) float32 — link matrices.
      v_re, v_im: (S, 3) float32 — color vectors.
      block: sites per Pallas grid step (S % block must be 0, or S < block).

    Returns:
      (out_re, out_im): (S, 3) float32.
    """
    s = u_re.shape[0]
    if s % block != 0:
        # Fall back to one whole-array block for ragged sizes.
        block = s
    grid = (s // block,)
    spec_mat = pl.BlockSpec((block, 3, 3), lambda i: (i, 0, 0))
    spec_vec = pl.BlockSpec((block, 3), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((s, 3), jnp.float32),
        jax.ShapeDtypeStruct((s, 3), jnp.float32),
    ]
    o_re, o_im = pl.pallas_call(
        _su3_kernel,
        grid=grid,
        in_specs=[spec_mat, spec_mat, spec_vec, spec_vec],
        out_specs=[spec_vec, spec_vec],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(u_re, u_im, v_re, v_im)
    return o_re, o_im


@functools.partial(jax.jit, static_argnames=("block",))
def su3_apply_dagger(u_re, u_im, v_re, v_im, block=DEFAULT_BLOCK):
    """Apply the adjoint links: out = U^dagger @ v.

    U^dagger = conj(U)^T, so re -> re^T, im -> -im^T; reuse the kernel.
    """
    u_re_t = jnp.swapaxes(u_re, 1, 2)
    u_im_t = -jnp.swapaxes(u_im, 1, 2)
    return su3_apply(u_re_t, u_im_t, v_re, v_im, block=block)
